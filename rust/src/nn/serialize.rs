//! Model serialization with bit-packed quantized weights.
//!
//! The paper's compression claim (Section 6.1: "we have compressed the
//! network by a factor of approximately 20") is about *storage*: a ternary
//! weight needs log₂3 ≈ 1.58 bits instead of 32.  This module makes that
//! claim measurable: a `.gpfq` file stores quantized layers as alphabet
//! *indices* packed at ⌈log₂M⌉ bits per weight plus one f32 `alpha` per
//! layer, while float layers (biases, unquantized layers, BN parameters)
//! stay f32.  `Saved::compression_vs_float()` reports the realized ratio.
//!
//! Since PR 6, packed layers stay **resident** after load: `load`
//! constructs `Layer::PackedDense` / `Layer::PackedConv` holding the
//! on-disk payload verbatim ([`crate::nn::kernels::PackedWeights`]), and
//! `Network::forward` computes on the indices directly — deserialization
//! never materializes the f32 weight matrix, and save→load→save is a byte
//! round trip for packed layers.
//!
//! Format (little-endian):
//!   magic "GPFQ" | u32 version | u32 layer count | layers...
//! Layer record: u8 tag, then tag-specific fields (see `write_layer`).

use std::io::{self, Read, Write};

use crate::error::{bail, Context, Result};

use crate::nn::activations::Activation;
use crate::nn::batchnorm::BatchNorm;
use crate::nn::conv::ImgShape;
use crate::nn::kernels::PackedWeights;
use crate::nn::matrix::Matrix;
use crate::nn::network::{Layer, Network, Shape};
use crate::quant::alphabet::Alphabet;

const MAGIC: &[u8; 4] = b"GPFQ";
const VERSION: u32 = 1;

// Load-path hardening caps.  A `.gpfq` file handed to `gpfq serve` is
// untrusted input: every length field is validated against these bounds
// *before* any allocation or arithmetic uses it, so a corrupt or malicious
// header fails with an error instead of an OOM abort, an arithmetic
// overflow, or an out-of-bounds panic in `unpack_indices`.
/// cap on any single matrix/bias/channel dimension
const MAX_DIM: usize = 1 << 24;
/// cap on total elements of one weight matrix (1 GiB of f32)
const MAX_ELEMS: usize = 1 << 28;
/// cap on alphabet size M (bits_per_index stays ≤ 20)
const MAX_LEVELS: usize = 1 << 20;

const TAG_DENSE: u8 = 1;
const TAG_CONV: u8 = 2;
const TAG_POOL: u8 = 3;
const TAG_BN: u8 = 4;

const ENC_F32: u8 = 0;
const ENC_PACKED: u8 = 1;

// ---------------------------------------------------------------------------
// bit packing
// ---------------------------------------------------------------------------

/// Bits needed per index for an M-character alphabet.
pub fn bits_per_index(m: usize) -> u32 {
    (usize::BITS - (m - 1).leading_zeros()).max(1)
}

/// Pack indices (< M) at `bits` bits each, LSB-first within bytes.
pub fn pack_indices(idx: &[usize], bits: u32) -> Vec<u8> {
    let mut out = vec![0u8; ((idx.len() as u64 * bits as u64).div_ceil(8)) as usize];
    let mut bitpos = 0u64;
    for &v in idx {
        debug_assert!(v < (1usize << bits));
        for b in 0..bits {
            if (v >> b) & 1 == 1 {
                out[(bitpos >> 3) as usize] |= 1 << (bitpos & 7);
            }
            bitpos += 1;
        }
    }
    out
}

/// Inverse of [`pack_indices`].
pub fn unpack_indices(bytes: &[u8], bits: u32, count: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0u64;
    for _ in 0..count {
        let mut v = 0usize;
        for b in 0..bits {
            let byte = bytes[(bitpos >> 3) as usize];
            if (byte >> (bitpos & 7)) & 1 == 1 {
                v |= 1 << b;
            }
            bitpos += 1;
        }
        out.push(v);
    }
    out
}

// ---------------------------------------------------------------------------
// weight encoding
// ---------------------------------------------------------------------------

/// What a weight record deserializes to: float layers come back as a
/// matrix, packed layers stay **resident** as their packed indices (no
/// eager unpack — `nn::kernels` computes on them directly).
enum ReadWeights {
    Float(Matrix),
    Packed(PackedWeights),
}

impl ReadWeights {
    fn rows(&self) -> usize {
        match self {
            ReadWeights::Float(w) => w.rows,
            ReadWeights::Packed(p) => p.rows(),
        }
    }
    fn cols(&self) -> usize {
        match self {
            ReadWeights::Float(w) => w.cols,
            ReadWeights::Packed(p) => p.cols(),
        }
    }
}

fn write_u32(out: &mut impl Write, v: u32) -> io::Result<()> {
    out.write_all(&v.to_le_bytes())
}

fn write_f32(out: &mut impl Write, v: f32) -> io::Result<()> {
    out.write_all(&v.to_le_bytes())
}

fn write_f32s(out: &mut impl Write, vs: &[f32]) -> io::Result<()> {
    for &v in vs {
        write_f32(out, v)?;
    }
    Ok(())
}

fn read_u32(inp: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32(inp: &mut impl Read) -> io::Result<f32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn read_f32s(inp: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(read_f32(inp)?);
    }
    Ok(out)
}

fn write_weights(out: &mut impl Write, w: &Matrix, alpha: Option<Alphabet>) -> io::Result<()> {
    if let Some(a) = alpha {
        if let Some(p) = PackedWeights::from_matrix(w, a) {
            return write_packed(out, &p);
        }
    }
    write_u32(out, w.rows as u32)?;
    write_u32(out, w.cols as u32)?;
    out.write_all(&[ENC_F32])?;
    write_f32s(out, &w.data)
}

/// Write an already-packed weight record: the resident payload goes to
/// disk verbatim, so save→load of a packed-resident network is a byte
/// round trip.
fn write_packed(out: &mut impl Write, p: &PackedWeights) -> io::Result<()> {
    write_u32(out, p.rows() as u32)?;
    write_u32(out, p.cols() as u32)?;
    out.write_all(&[ENC_PACKED])?;
    write_f32(out, p.alphabet().alpha)?;
    write_u32(out, p.alphabet().m as u32)?;
    write_u32(out, p.bytes().len() as u32)?;
    out.write_all(p.bytes())
}

fn read_weights(inp: &mut impl Read) -> Result<ReadWeights> {
    let rows = read_u32(inp)? as usize;
    let cols = read_u32(inp)? as usize;
    if rows > MAX_DIM || cols > MAX_DIM {
        bail!("implausible weight shape {rows}x{cols}");
    }
    let elems = rows
        .checked_mul(cols)
        .filter(|&n| n <= MAX_ELEMS)
        .ok_or_else(|| crate::error::format_err!("weight matrix {rows}x{cols} exceeds element cap"))?;
    let mut enc = [0u8; 1];
    inp.read_exact(&mut enc)?;
    match enc[0] {
        ENC_F32 => Ok(ReadWeights::Float(Matrix::from_vec(rows, cols, read_f32s(inp, elems)?))),
        ENC_PACKED => {
            let alpha = read_f32(inp)?;
            if !alpha.is_finite() || alpha <= 0.0 {
                bail!("corrupt packed layer: alpha {alpha}");
            }
            let m = read_u32(inp)? as usize;
            if !(2..=MAX_LEVELS).contains(&m) {
                bail!("corrupt packed layer: alphabet size {m}");
            }
            let a = Alphabet::new(alpha, m);
            let bits = bits_per_index(m);
            let nbytes = read_u32(inp)? as usize;
            // the payload length is implied by the shape; a mismatch means
            // a corrupt stream (and a short one would index out of bounds
            // inside unpack_indices)
            let expected = (elems as u64 * bits as u64).div_ceil(8) as usize;
            if nbytes != expected {
                bail!("packed payload {nbytes} bytes, shape implies {expected}");
            }
            let mut bytes = vec![0u8; nbytes];
            inp.read_exact(&mut bytes)?;
            // the payload stays resident; from_raw_parts re-checks the
            // length and rejects any index ≥ M (⌈log₂M⌉ bits can encode
            // past M-1 for non-power-of-two alphabets) so a corrupt
            // payload fails here, never inside a forward pass
            Ok(ReadWeights::Packed(PackedWeights::from_raw_parts(rows, cols, a, bytes)?))
        }
        other => bail!("unknown weight encoding {other}"),
    }
}

// ---------------------------------------------------------------------------
// network (de)serialization
// ---------------------------------------------------------------------------

/// Per-layer alphabet hints for packed encoding (layer index → alphabet),
/// typically taken from `QuantOutcome::layer_reports`.
pub type AlphabetHints = std::collections::BTreeMap<usize, Alphabet>;

/// Serialize a network; layers with an alphabet hint whose weights check
/// out are bit-packed.
pub fn save(net: &Network, hints: &AlphabetHints, out: &mut impl Write) -> Result<()> {
    out.write_all(MAGIC)?;
    write_u32(out, VERSION)?;
    // input shape
    match net.input {
        Shape::Flat(n) => {
            write_u32(out, 0)?;
            write_u32(out, n as u32)?;
        }
        Shape::Img(s) => {
            write_u32(out, 1)?;
            write_u32(out, s.h as u32)?;
            write_u32(out, s.w as u32)?;
            write_u32(out, s.c as u32)?;
        }
    }
    write_u32(out, net.layers.len() as u32)?;
    for (i, layer) in net.layers.iter().enumerate() {
        match layer {
            Layer::Dense { w, b, act } => {
                out.write_all(&[TAG_DENSE])?;
                out.write_all(&[matches!(act, Activation::Relu) as u8])?;
                write_weights(out, w, hints.get(&i).copied())?;
                write_u32(out, b.len() as u32)?;
                write_f32s(out, b)?;
            }
            Layer::Conv { k, b, kh, kw, stride, act, in_shape } => {
                out.write_all(&[TAG_CONV])?;
                out.write_all(&[matches!(act, Activation::Relu) as u8])?;
                write_u32(out, *kh as u32)?;
                write_u32(out, *kw as u32)?;
                write_u32(out, *stride as u32)?;
                write_u32(out, in_shape.h as u32)?;
                write_u32(out, in_shape.w as u32)?;
                write_u32(out, in_shape.c as u32)?;
                write_weights(out, k, hints.get(&i).copied())?;
                write_u32(out, b.len() as u32)?;
                write_f32s(out, b)?;
            }
            Layer::MaxPool { size, in_shape } => {
                out.write_all(&[TAG_POOL])?;
                write_u32(out, *size as u32)?;
                write_u32(out, in_shape.h as u32)?;
                write_u32(out, in_shape.w as u32)?;
                write_u32(out, in_shape.c as u32)?;
            }
            Layer::BatchNorm(bn) => {
                out.write_all(&[TAG_BN])?;
                write_u32(out, bn.channels as u32)?;
                write_f32(out, bn.eps)?;
                write_f32s(out, &bn.gamma)?;
                write_f32s(out, &bn.beta)?;
                write_f32s(out, &bn.running_mean)?;
                write_f32s(out, &bn.running_var)?;
            }
            // packed-resident layers reuse the dense/conv tags: the on-disk
            // format is unchanged, the payload is just written verbatim
            Layer::PackedDense { w, b, act } => {
                out.write_all(&[TAG_DENSE])?;
                out.write_all(&[matches!(act, Activation::Relu) as u8])?;
                write_packed(out, w)?;
                write_u32(out, b.len() as u32)?;
                write_f32s(out, b)?;
            }
            Layer::PackedConv { k, b, kh, kw, stride, act, in_shape } => {
                out.write_all(&[TAG_CONV])?;
                out.write_all(&[matches!(act, Activation::Relu) as u8])?;
                write_u32(out, *kh as u32)?;
                write_u32(out, *kw as u32)?;
                write_u32(out, *stride as u32)?;
                write_u32(out, in_shape.h as u32)?;
                write_u32(out, in_shape.w as u32)?;
                write_u32(out, in_shape.c as u32)?;
                write_packed(out, k)?;
                write_u32(out, b.len() as u32)?;
                write_f32s(out, b)?;
            }
        }
    }
    Ok(())
}

/// Deserialize a network saved by [`save`].
pub fn load(inp: &mut impl Read) -> Result<Network> {
    let mut magic = [0u8; 4];
    inp.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("not a GPFQ model file");
    }
    let version = read_u32(inp)?;
    if version != VERSION {
        bail!("unsupported model version {version}");
    }
    // reject image shapes whose element product overflows or exceeds the
    // allocation cap: `ImgShape::len` multiplies unchecked, so an
    // unvalidated shape could wrap (or panic in debug) downstream
    let checked_img = |li: usize, s: ImgShape| -> Result<ImgShape> {
        if s.h > MAX_DIM || s.w > MAX_DIM || s.c > MAX_DIM {
            bail!("layer {li}: implausible image shape {}x{}x{}", s.h, s.w, s.c);
        }
        s.h.checked_mul(s.w)
            .and_then(|n| n.checked_mul(s.c))
            .filter(|&n| n <= MAX_ELEMS)
            .ok_or_else(|| {
                crate::error::format_err!(
                    "layer {li}: image shape {}x{}x{} exceeds element cap",
                    s.h,
                    s.w,
                    s.c
                )
            })?;
        Ok(s)
    };
    let input = match read_u32(inp)? {
        0 => Shape::Flat(read_u32(inp)? as usize),
        1 => Shape::Img(checked_img(
            0,
            ImgShape {
                h: read_u32(inp)? as usize,
                w: read_u32(inp)? as usize,
                c: read_u32(inp)? as usize,
            },
        )?),
        other => bail!("bad input-shape tag {other}"),
    };
    let n_layers = read_u32(inp)? as usize;
    if n_layers > 10_000 {
        bail!("implausible layer count {n_layers}");
    }
    // rebuild through the builder machinery to restore shape bookkeeping
    let mut layers = Vec::with_capacity(n_layers);
    let mut shapes = Vec::with_capacity(n_layers);
    let mut cur = input;
    for li in 0..n_layers {
        let mut tag = [0u8; 1];
        inp.read_exact(&mut tag).with_context(|| format!("layer {li} tag"))?;
        match tag[0] {
            TAG_DENSE => {
                let mut actb = [0u8; 1];
                inp.read_exact(&mut actb)?;
                let act = if actb[0] == 1 { Activation::Relu } else { Activation::None };
                let w = read_weights(inp)?;
                let blen = read_u32(inp)? as usize;
                if w.cols() != blen {
                    bail!("layer {li}: bias length {blen} != neurons {}", w.cols());
                }
                let b = read_f32s(inp, blen)?;
                // the chain invariant: this layer must consume exactly the
                // width the previous layer produced, or the first forward
                // pass would assert inside the GEMM (on a serve executor
                // thread, for a file that "loaded fine")
                if w.rows() != cur.len() {
                    bail!(
                        "layer {li}: dense expects input width {}, chain provides {}",
                        w.rows(),
                        cur.len()
                    );
                }
                cur = Shape::Flat(w.cols());
                // packed weights stay resident: the layer dispatches to the
                // packed-domain kernel instead of an eager unpack
                layers.push(match w {
                    ReadWeights::Float(w) => Layer::Dense { w, b, act },
                    ReadWeights::Packed(w) => Layer::PackedDense { w, b, act },
                });
            }
            TAG_CONV => {
                let mut actb = [0u8; 1];
                inp.read_exact(&mut actb)?;
                let act = if actb[0] == 1 { Activation::Relu } else { Activation::None };
                let kh = read_u32(inp)? as usize;
                let kw = read_u32(inp)? as usize;
                let stride = read_u32(inp)? as usize;
                let in_shape = checked_img(
                    li,
                    ImgShape {
                        h: read_u32(inp)? as usize,
                        w: read_u32(inp)? as usize,
                        c: read_u32(inp)? as usize,
                    },
                )?;
                if kh == 0 || kw == 0 || stride == 0 || kh > in_shape.h || kw > in_shape.w {
                    bail!(
                        "layer {li}: kernel {kh}x{kw} stride {stride} does not fit input {}x{}",
                        in_shape.h,
                        in_shape.w
                    );
                }
                let k = read_weights(inp)?;
                let patch = kh
                    .checked_mul(kw)
                    .and_then(|n| n.checked_mul(in_shape.c))
                    .ok_or_else(|| crate::error::format_err!("layer {li}: patch size overflow"))?;
                if k.rows() != patch {
                    bail!("layer {li}: kernel rows {} != kh*kw*cin {patch}", k.rows());
                }
                let blen = read_u32(inp)? as usize;
                if blen != k.cols() {
                    bail!("layer {li}: bias length {blen} != channels {}", k.cols());
                }
                let b = read_f32s(inp, blen)?;
                // the chain invariant (see the dense arm): im2col asserts
                // x.cols == in_shape.len(), so a drifted conv input shape
                // would panic the first forward instead of failing the load
                if in_shape.len() != cur.len() {
                    bail!(
                        "layer {li}: conv input shape {} elements, chain provides {}",
                        in_shape.len(),
                        cur.len()
                    );
                }
                let out_shape = ImgShape {
                    h: crate::nn::conv::conv_out(in_shape.h, kh, stride),
                    w: crate::nn::conv::conv_out(in_shape.w, kw, stride),
                    c: k.cols(),
                };
                cur = Shape::Img(out_shape);
                layers.push(match k {
                    ReadWeights::Float(k) => Layer::Conv { k, b, kh, kw, stride, act, in_shape },
                    ReadWeights::Packed(k) => {
                        Layer::PackedConv { k, b, kh, kw, stride, act, in_shape }
                    }
                });
            }
            TAG_POOL => {
                let size = read_u32(inp)? as usize;
                let in_shape = checked_img(
                    li,
                    ImgShape {
                        h: read_u32(inp)? as usize,
                        w: read_u32(inp)? as usize,
                        c: read_u32(inp)? as usize,
                    },
                )?;
                if size == 0 || size > in_shape.h || size > in_shape.w {
                    let (h, w) = (in_shape.h, in_shape.w);
                    bail!("layer {li}: pool size {size} does not fit {h}x{w}");
                }
                // chain invariant: maxpool_forward asserts
                // x.cols == in_shape.len()
                if in_shape.len() != cur.len() {
                    bail!(
                        "layer {li}: pool input shape {} elements, chain provides {}",
                        in_shape.len(),
                        cur.len()
                    );
                }
                cur = Shape::Img(ImgShape { h: in_shape.h / size, w: in_shape.w / size, c: in_shape.c });
                layers.push(Layer::MaxPool { size, in_shape });
            }
            TAG_BN => {
                let channels = read_u32(inp)? as usize;
                if channels == 0 || channels > MAX_DIM {
                    bail!("layer {li}: implausible BN channel count {channels}");
                }
                // BatchNorm::forward_infer asserts cols % channels == 0 —
                // enforce it at load so a crafted file cannot detonate a
                // forward pass instead of failing here
                if cur.len() % channels != 0 {
                    bail!(
                        "layer {li}: BN channels {channels} do not divide chain width {}",
                        cur.len()
                    );
                }
                let mut bn = BatchNorm::new(channels);
                bn.eps = read_f32(inp)?;
                bn.gamma = read_f32s(inp, channels)?;
                bn.beta = read_f32s(inp, channels)?;
                bn.running_mean = read_f32s(inp, channels)?;
                bn.running_var = read_f32s(inp, channels)?;
                layers.push(Layer::BatchNorm(bn));
            }
            other => bail!("layer {li}: unknown tag {other}"),
        }
        shapes.push(cur);
    }
    Ok(Network::from_parts(input, layers, shapes))
}

/// Convenience: save to / load from a file path.
pub fn save_file(net: &Network, hints: &AlphabetHints, path: &std::path::Path) -> Result<u64> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    save(net, hints, &mut f)?;
    f.flush()?;
    Ok(std::fs::metadata(path)?.len())
}

pub fn load_file(path: &std::path::Path) -> Result<Network> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    load(&mut f)
}

/// Alphabet hints from a pipeline outcome.
pub fn hints_from_outcome(outcome: &crate::coordinator::pipeline::QuantOutcome) -> AlphabetHints {
    outcome
        .layer_reports
        .iter()
        .map(|r| (r.layer_index, Alphabet::new(r.alpha, r.levels)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{quantize_network, PipelineConfig};
    use crate::data::rng::Pcg;
    use crate::nn::network::{cifar_cnn, mnist_mlp};

    #[test]
    fn pack_unpack_roundtrip() {
        for m in [2usize, 3, 4, 8, 16, 31] {
            let bits = bits_per_index(m);
            let mut rng = Pcg::seed(m as u64);
            let idx: Vec<usize> = (0..1000).map(|_| rng.below(m)).collect();
            let packed = pack_indices(&idx, bits);
            assert_eq!(unpack_indices(&packed, bits, idx.len()), idx, "M={m}");
            // size check: exactly ceil(n*bits/8)
            assert_eq!(packed.len(), (1000 * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn bits_per_index_values() {
        assert_eq!(bits_per_index(2), 1);
        assert_eq!(bits_per_index(3), 2);
        assert_eq!(bits_per_index(4), 2);
        assert_eq!(bits_per_index(16), 4);
        assert_eq!(bits_per_index(17), 5);
    }

    #[test]
    fn float_network_roundtrip() {
        let net = mnist_mlp(1, 20, &[12, 8], 3);
        let mut buf = Vec::new();
        save(&net, &AlphabetHints::new(), &mut buf).unwrap();
        let back = load(&mut &buf[..]).unwrap();
        assert_eq!(back.summary(), net.summary());
        let mut rng = Pcg::seed(2);
        let x = Matrix::from_vec(4, 20, rng.normal_vec(80));
        assert_eq!(net.forward(&x).data, back.forward(&x).data);
    }

    #[test]
    fn cnn_roundtrip_with_bn_and_pool() {
        let img = ImgShape { h: 10, w: 10, c: 2 };
        let net = cifar_cnn(3, img, &[4], 16, 3);
        let mut buf = Vec::new();
        save(&net, &AlphabetHints::new(), &mut buf).unwrap();
        let back = load(&mut &buf[..]).unwrap();
        let mut rng = Pcg::seed(4);
        let x = Matrix::from_vec(3, img.len(), rng.normal_vec(3 * img.len()));
        let d: f32 = net
            .forward(&x)
            .data
            .iter()
            .zip(&back.forward(&x).data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(d < 1e-6, "forward mismatch {d}");
    }

    #[test]
    fn quantized_network_packs_and_compresses() {
        let mut rng = Pcg::seed(5);
        let net = mnist_mlp(6, 200, &[128, 64], 10);
        let x = Matrix::from_vec(64, 200, rng.normal_vec(64 * 200));
        let out = quantize_network(&net, &x, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
        let hints = hints_from_outcome(&out);
        let mut packed = Vec::new();
        save(&out.network, &hints, &mut packed).unwrap();
        let mut float = Vec::new();
        save(&out.network, &AlphabetHints::new(), &mut float).unwrap();
        let ratio = float.len() as f64 / packed.len() as f64;
        // ternary: 2 bits packed vs 32 ⇒ ~16x on the weight payload; with
        // float biases/BN overhead we still expect >8x on this net
        assert!(ratio > 8.0, "compression ratio {ratio:.1} too low ({} vs {})", float.len(), packed.len());
        // and the packed model must act identically
        let back = load(&mut &packed[..]).unwrap();
        let xt = Matrix::from_vec(8, 200, rng.normal_vec(1600));
        let d: f32 = out
            .network
            .forward(&xt)
            .data
            .iter()
            .zip(&back.forward(&xt).data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(d < 1e-4, "packed forward mismatch {d}");
    }

    #[test]
    fn load_keeps_packed_layers_resident_and_roundtrips_bytes() {
        let mut rng = Pcg::seed(21);
        let net = mnist_mlp(22, 40, &[16], 4);
        let x = Matrix::from_vec(24, 40, rng.normal_vec(24 * 40));
        let out = quantize_network(&net, &x, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
        let hints = hints_from_outcome(&out);
        let mut buf = Vec::new();
        save(&out.network, &hints, &mut buf).unwrap();
        let back = load(&mut &buf[..]).unwrap();
        // quantized layers come back packed-resident, not as f32 matrices
        assert!(back.summary().contains("pdense"), "summary: {}", back.summary());
        assert_eq!(crate::nn::kernels::packed_layer_count(&back), out.layer_reports.len());
        // save→load→save is a byte round trip (payload stays verbatim)
        let mut buf2 = Vec::new();
        save(&back, &AlphabetHints::new(), &mut buf2).unwrap();
        assert_eq!(buf, buf2);
        // and the packed forward is bit-identical to eager unpacking
        let xt = Matrix::from_vec(6, 40, rng.normal_vec(240));
        let unpacked = crate::nn::kernels::unpack_network(&back);
        assert_eq!(back.forward(&xt).data, unpacked.forward(&xt).data);
    }

    #[test]
    fn refuses_garbage() {
        assert!(load(&mut &b"NOPE"[..]).is_err());
        let mut buf = Vec::new();
        save(&mnist_mlp(0, 4, &[3], 2), &AlphabetHints::new(), &mut buf).unwrap();
        buf[4] = 99; // version
        assert!(load(&mut &buf[..]).is_err());
        // truncation
        let mut buf2 = Vec::new();
        save(&mnist_mlp(0, 4, &[3], 2), &AlphabetHints::new(), &mut buf2).unwrap();
        buf2.truncate(buf2.len() / 2);
        assert!(load(&mut &buf2[..]).is_err());
    }

    /// A writer for hand-crafted malicious headers.
    fn le32(v: u32) -> [u8; 4] {
        v.to_le_bytes()
    }

    fn header(n_layers: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&le32(VERSION));
        b.extend_from_slice(&le32(0)); // flat input
        b.extend_from_slice(&le32(8));
        b.extend_from_slice(&le32(n_layers));
        b
    }

    #[test]
    fn load_rejects_implausible_weight_shapes_without_allocating() {
        // a dense layer claiming a (2^31 x 2^31) matrix: must error out on
        // the cap check, never attempt the allocation
        let mut b = header(1);
        b.push(TAG_DENSE);
        b.push(0); // act
        b.extend_from_slice(&le32(1 << 31));
        b.extend_from_slice(&le32(1 << 31));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("implausible weight shape"), "{e:#}");
        // plausible dims whose product overflows the element cap
        let mut b = header(1);
        b.push(TAG_DENSE);
        b.push(0);
        b.extend_from_slice(&le32(1 << 23));
        b.extend_from_slice(&le32(1 << 23));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("element cap"), "{e:#}");
    }

    #[test]
    fn load_rejects_huge_bias_before_reading_it() {
        // 2x2 f32 weights, then a bias length that disagrees with cols —
        // must fail on the length check, not try to read 4B floats
        let mut b = header(1);
        b.push(TAG_DENSE);
        b.push(0);
        b.extend_from_slice(&le32(2));
        b.extend_from_slice(&le32(2));
        b.push(ENC_F32);
        for _ in 0..4 {
            b.extend_from_slice(&0.5f32.to_le_bytes());
        }
        b.extend_from_slice(&le32(u32::MAX));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("bias length"), "{e:#}");
    }

    #[test]
    fn load_rejects_corrupt_packed_payloads() {
        let packed_layer = |m: u32, nbytes: u32, payload: &[u8], alpha: f32| {
            let mut b = header(1);
            b.push(TAG_DENSE);
            b.push(0);
            b.extend_from_slice(&le32(2)); // 2x2
            b.extend_from_slice(&le32(2));
            b.push(ENC_PACKED);
            b.extend_from_slice(&alpha.to_le_bytes());
            b.extend_from_slice(&le32(m));
            b.extend_from_slice(&le32(nbytes));
            b.extend_from_slice(payload);
            b
        };
        // payload length disagreeing with the shape (the pre-fix OOB panic
        // path in unpack_indices)
        let e = load(&mut &packed_layer(3, 0, &[], 1.0)[..]).unwrap_err();
        assert!(format!("{e:#}").contains("shape implies"), "{e:#}");
        // alphabet size 0/1 (Alphabet::new would assert) and absurd M
        for m in [0u32, 1, 1 << 30] {
            let e = load(&mut &packed_layer(m, 1, &[0], 1.0)[..]).unwrap_err();
            assert!(format!("{e:#}").contains("alphabet size"), "M={m}: {e:#}");
        }
        // non-finite / non-positive alpha (Alphabet::new would assert)
        for alpha in [f32::NAN, f32::INFINITY, 0.0, -1.0] {
            let e = load(&mut &packed_layer(3, 1, &[0], alpha)[..]).unwrap_err();
            assert!(format!("{e:#}").contains("alpha"), "alpha={alpha}: {e:#}");
        }
        // an index past M-1 inside a valid-length payload (M=3 packs 2
        // bits: index 3 is encodable but invalid) — 4 indices of value 3
        let e = load(&mut &packed_layer(3, 1, &[0xFF], 1.0)[..]).unwrap_err();
        assert!(format!("{e:#}").contains("out of range"), "{e:#}");
    }

    #[test]
    fn load_rejects_corrupt_conv_pool_bn_records() {
        // conv kernel that does not fit its input
        let mut b = header(1);
        b.push(TAG_CONV);
        b.push(0);
        b.extend_from_slice(&le32(5)); // kh
        b.extend_from_slice(&le32(5)); // kw
        b.extend_from_slice(&le32(1)); // stride
        b.extend_from_slice(&le32(3)); // h < kh
        b.extend_from_slice(&le32(3));
        b.extend_from_slice(&le32(1));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("does not fit"), "{e:#}");
        // zero-size pool
        let mut b = header(1);
        b.push(TAG_POOL);
        b.extend_from_slice(&le32(0));
        b.extend_from_slice(&le32(4));
        b.extend_from_slice(&le32(4));
        b.extend_from_slice(&le32(1));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("pool size"), "{e:#}");
        // BN claiming 2^31 channels: rejected before the 4 huge reads
        let mut b = header(1);
        b.push(TAG_BN);
        b.extend_from_slice(&le32(1 << 31));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("BN channel"), "{e:#}");
    }

    #[test]
    fn load_rejects_mismatched_layer_chain() {
        // each record is self-consistent but disagrees with the running
        // shape of the chain — such files used to load fine and then
        // panic inside the first forward pass (on a serve executor
        // thread), which is exactly the failure mode the panic-path lint
        // polices on this surface
        //
        // dense expecting width 5 after a flat-8 input
        let mut b = header(1);
        b.push(TAG_DENSE);
        b.push(0);
        b.extend_from_slice(&le32(5)); // rows != 8
        b.extend_from_slice(&le32(3));
        b.push(ENC_F32);
        for _ in 0..15 {
            b.extend_from_slice(&0.5f32.to_le_bytes());
        }
        b.extend_from_slice(&le32(3)); // bias len == cols: self-consistent
        for _ in 0..3 {
            b.extend_from_slice(&0.0f32.to_le_bytes());
        }
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("chain provides"), "{e:#}");

        // 1x1 conv whose declared input (2x2x1 = 4 elements) disagrees
        // with the flat-8 chain; kernel and bias are self-consistent
        let mut b = header(1);
        b.push(TAG_CONV);
        b.push(0);
        for v in [1u32, 1, 1, 2, 2, 1] {
            b.extend_from_slice(&le32(v)); // kh kw stride h w c
        }
        b.extend_from_slice(&le32(1)); // kernel rows = kh*kw*c
        b.extend_from_slice(&le32(1)); // 1 output channel
        b.push(ENC_F32);
        b.extend_from_slice(&0.5f32.to_le_bytes());
        b.extend_from_slice(&le32(1)); // bias len == channels
        b.extend_from_slice(&0.0f32.to_le_bytes());
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("chain provides"), "{e:#}");

        // pool over a 2x2x1 input on the flat-8 chain
        let mut b = header(1);
        b.push(TAG_POOL);
        for v in [2u32, 2, 2, 1] {
            b.extend_from_slice(&le32(v));
        }
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("chain provides"), "{e:#}");

        // BN whose channel count does not divide the chain width
        let mut b = header(1);
        b.push(TAG_BN);
        b.extend_from_slice(&le32(3)); // 3 does not divide 8
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("do not divide"), "{e:#}");
    }

    #[test]
    fn load_rejects_overflowing_image_shapes() {
        // an image input whose h*w*c overflows usize multiplication: the
        // unchecked ImgShape::len would wrap (or panic in debug builds)
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&le32(VERSION));
        b.extend_from_slice(&le32(1)); // img input
        for _ in 0..3 {
            b.extend_from_slice(&le32(1 << 24)); // == MAX_DIM, product 2^72
        }
        b.extend_from_slice(&le32(1));
        let e = load(&mut &b[..]).unwrap_err();
        assert!(format!("{e:#}").contains("element cap"), "{e:#}");
    }

    #[test]
    fn non_alphabet_weights_fall_back_to_f32() {
        let net = mnist_mlp(7, 10, &[5], 2); // float weights, not in alphabet
        let mut hints = AlphabetHints::new();
        hints.insert(0, Alphabet::ternary(1.0));
        let mut buf = Vec::new();
        save(&net, &hints, &mut buf).unwrap();
        let back = load(&mut &buf[..]).unwrap();
        assert_eq!(
            back.layers[0].weights().unwrap().data,
            net.layers[0].weights().unwrap().data,
            "float fallback must be lossless"
        );
    }
}
