//! Batch normalization (Ioffe & Szegedy 2015), used by both of the paper's
//! experimental architectures (Section 6.1/6.2).
//!
//! Features are normalized per channel: for dense activations the channel
//! is the column; for conv activations (NHWC flattened) it is `col % c`.
//! Training uses batch statistics and maintains running estimates;
//! inference uses the running estimates.  The quantization pipeline treats
//! BN layers as pass-through (they hold no quantizable weight matrix) —
//! exactly what the paper does.

use crate::nn::matrix::Matrix;

#[derive(Debug, Clone)]
pub struct BatchNorm {
    /// number of channels normalized over
    pub channels: usize,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub eps: f32,
    pub momentum: f32,
}

/// Cached forward state for the backward pass.
#[derive(Debug, Clone)]
pub struct BnCache {
    pub x_hat: Matrix,
    pub inv_std: Vec<f32>,
    pub mean: Vec<f32>,
}

impl BatchNorm {
    pub fn new(channels: usize) -> Self {
        BatchNorm {
            channels,
            gamma: vec![1.0; channels],
            beta: vec![0.0; channels],
            running_mean: vec![0.0; channels],
            running_var: vec![1.0; channels],
            eps: 1e-5,
            momentum: 0.9,
        }
    }

    #[inline]
    fn ch(&self, col: usize) -> usize {
        col % self.channels
    }

    /// Per-channel `1/√(running_var + eps)`, exactly as inference-mode
    /// forward computes it.  Shared by [`BatchNorm::forward_infer`] and
    /// the fused GEMM epilogue (`nn::kernels::Epilogue`) so both paths
    /// start from bit-identical scales.
    pub fn inv_std_infer(&self) -> Vec<f32> {
        self.running_var.iter().map(|v| 1.0 / (v + self.eps).sqrt()).collect()
    }

    /// The inference-mode per-element affine, the single source of truth
    /// for its f32 expression (association order included):
    /// `gamma·(v − mean)·inv_std + beta`.  Both [`forward_infer`]
    /// (unfused, the frozen oracle) and the fused epilogue call this, so
    /// fused ≡ unfused cannot drift.
    ///
    /// [`forward_infer`]: BatchNorm::forward_infer
    #[inline]
    pub fn affine_one(&self, v: f32, ch: usize, inv_std: &[f32]) -> f32 {
        self.gamma[ch] * (v - self.running_mean[ch]) * inv_std[ch] + self.beta[ch]
    }

    /// Inference-mode forward using running statistics.
    pub fn forward_infer(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols % self.channels, 0, "cols {} not divisible by channels {}", x.cols, self.channels);
        let mut out = x.clone();
        let inv_std = self.inv_std_infer();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v = self.affine_one(*v, c % self.channels, &inv_std);
            }
        }
        out
    }

    /// Training-mode forward using batch statistics; updates running stats.
    pub fn forward_train(&mut self, x: &Matrix) -> (Matrix, BnCache) {
        assert_eq!(x.cols % self.channels, 0);
        let groups = x.cols / self.channels; // spatial positions per channel
        let count = (x.rows * groups) as f32;
        let mut mean = vec![0.0f32; self.channels];
        for r in 0..x.rows {
            for (c, &v) in x.row(r).iter().enumerate() {
                mean[self.ch(c)] += v;
            }
        }
        for m in &mut mean {
            *m /= count;
        }
        let mut var = vec![0.0f32; self.channels];
        for r in 0..x.rows {
            for (c, &v) in x.row(r).iter().enumerate() {
                let d = v - mean[self.ch(c)];
                var[self.ch(c)] += d * d;
            }
        }
        for v in &mut var {
            *v /= count;
        }
        let inv_std: Vec<f32> = var.iter().map(|v| 1.0 / (v + self.eps).sqrt()).collect();
        let mut x_hat = x.clone();
        for r in 0..x_hat.rows {
            let row = x_hat.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                let ch = c % self.channels;
                *v = (*v - mean[ch]) * inv_std[ch];
            }
        }
        let mut out = x_hat.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                let ch = c % self.channels;
                *v = self.gamma[ch] * *v + self.beta[ch];
            }
        }
        for ch in 0..self.channels {
            self.running_mean[ch] = self.momentum * self.running_mean[ch] + (1.0 - self.momentum) * mean[ch];
            self.running_var[ch] = self.momentum * self.running_var[ch] + (1.0 - self.momentum) * var[ch];
        }
        (out, BnCache { x_hat, inv_std, mean })
    }

    /// Backward pass; returns dx and accumulates (dgamma, dbeta).
    pub fn backward(&self, cache: &BnCache, dout: &Matrix, dgamma: &mut [f32], dbeta: &mut [f32]) -> Matrix {
        let groups = dout.cols / self.channels;
        let count = (dout.rows * groups) as f32;
        // per-channel sums
        let mut sum_dy = vec![0.0f32; self.channels];
        let mut sum_dy_xhat = vec![0.0f32; self.channels];
        for r in 0..dout.rows {
            for (c, &dy) in dout.row(r).iter().enumerate() {
                let ch = c % self.channels;
                sum_dy[ch] += dy;
                sum_dy_xhat[ch] += dy * cache.x_hat.at(r, c);
            }
        }
        for ch in 0..self.channels {
            dgamma[ch] += sum_dy_xhat[ch];
            dbeta[ch] += sum_dy[ch];
        }
        let mut dx = Matrix::zeros(dout.rows, dout.cols);
        for r in 0..dout.rows {
            for c in 0..dout.cols {
                let ch = c % self.channels;
                let dy = dout.at(r, c);
                let xh = cache.x_hat.at(r, c);
                let v = self.gamma[ch] * cache.inv_std[ch] / count
                    * (count * dy - sum_dy[ch] - xh * sum_dy_xhat[ch]);
                *dx.at_mut(r, c) = v;
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Pcg;

    #[test]
    fn train_forward_normalizes() {
        let mut rng = Pcg::seed(1);
        let mut bn = BatchNorm::new(3);
        let x = Matrix::from_vec(64, 3, rng.uniform_vec(192, 5.0, 9.0));
        let (out, _) = bn.forward_train(&x);
        for ch in 0..3 {
            let col: Vec<f64> = (0..64).map(|r| out.at(r, ch) as f64).collect();
            let mean: f64 = col.iter().sum::<f64>() / 64.0;
            let var: f64 = col.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / 64.0;
            assert!(mean.abs() < 1e-4, "ch{ch} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "ch{ch} var {var}");
        }
    }

    #[test]
    fn infer_uses_running_stats() {
        let mut bn = BatchNorm::new(1);
        bn.running_mean = vec![2.0];
        bn.running_var = vec![4.0];
        bn.gamma = vec![3.0];
        bn.beta = vec![1.0];
        let x = Matrix::from_vec(1, 1, vec![4.0]);
        let out = bn.forward_infer(&x);
        // 3 * (4-2)/2 + 1 = 4
        assert!((out.at(0, 0) - 4.0).abs() < 1e-3);
    }

    #[test]
    fn conv_channel_grouping() {
        // 2 channels over 2 spatial positions: cols [c0 c1 c0 c1]
        let mut bn = BatchNorm::new(2);
        let x = Matrix::from_vec(1, 4, vec![1.0, 10.0, 3.0, 20.0]);
        let (out, _) = bn.forward_train(&x);
        // channel 0 values {1,3} normalize to {-1, 1}; channel 1 {10,20} too
        assert!((out.at(0, 0) + 1.0).abs() < 0.01);
        assert!((out.at(0, 2) - 1.0).abs() < 0.01);
        assert!((out.at(0, 1) + 1.0).abs() < 0.01);
        assert!((out.at(0, 3) - 1.0).abs() < 0.01);
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Pcg::seed(2);
        let mut bn = BatchNorm::new(2);
        bn.gamma = vec![1.3, 0.7];
        bn.beta = vec![0.1, -0.2];
        let x = Matrix::from_vec(5, 2, rng.normal_vec(10));
        // loss = sum(out * R) for fixed random R
        let rmat = Matrix::from_vec(5, 2, rng.normal_vec(10));
        let loss = |bn: &mut BatchNorm, x: &Matrix| -> f64 {
            let (out, _) = bn.forward_train(x);
            out.data.iter().zip(&rmat.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let (_, cache) = bn.clone().forward_train(&x);
        let mut dgamma = vec![0.0; 2];
        let mut dbeta = vec![0.0; 2];
        let dx = bn.backward(&cache, &rmat, &mut dgamma, &mut dbeta);
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (loss(&mut bn.clone(), &xp) - loss(&mut bn.clone(), &xm)) / (2.0 * eps as f64);
            assert!(
                (fd - dx.data[idx] as f64).abs() < 2e-2 * fd.abs().max(1.0),
                "idx {idx}: fd {fd} vs dx {}",
                dx.data[idx]
            );
        }
        // dbeta = column sums of dout per channel
        assert!((dbeta[0] as f64 - (0..5).map(|r| rmat.at(r, 0) as f64).sum::<f64>()).abs() < 1e-4);
    }

    #[test]
    fn running_stats_update() {
        let mut bn = BatchNorm::new(1);
        bn.momentum = 0.5;
        let x = Matrix::from_vec(4, 1, vec![2.0, 2.0, 2.0, 2.0]);
        bn.forward_train(&x);
        assert!((bn.running_mean[0] - 1.0).abs() < 1e-6); // 0.5*0 + 0.5*2
        assert!((bn.running_var[0] - 0.5).abs() < 1e-6); // 0.5*1 + 0.5*0
    }
}
