//! Scheduler equivalence: the paper's Section 4 claim is that GPFQ is
//! "parallelizable across neurons in a layer" — which is only true if the
//! parallel schedule cannot change the numbers.  These tests pin that down
//! hard: multi-threaded quantization must be **bit-identical** to the serial
//! walk on a fixed-seed synthetic layer, for every worker count, block
//! width, and lane/tail path mix — and the worker pool must demonstrably
//! run blocks concurrently rather than degenerate to a serial loop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use gpfq::coordinator::executor::{Executor, Path};
use gpfq::coordinator::pipeline::{quantize_network, PipelineConfig};
use gpfq::coordinator::scheduler::{run_jobs, SchedulerConfig};
use gpfq::data::rng::Pcg;
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::mnist_mlp;
use gpfq::quant::alphabet::Alphabet;
use gpfq::quant::gpfq::{
    gpfq_layer, gpfq_layer_parallel, gpfq_layer_range, gpfq_neuron, LayerData, LANES,
};

fn fixed_seed_layer(seed: u64, m: usize, n: usize, neurons: usize) -> (LayerData, Matrix) {
    let mut rng = Pcg::seed(seed);
    let y = Matrix::from_vec(m, n, rng.normal_vec(m * n));
    // distinct quantized-stream matrix: exercise the general eq. (3) path
    let mut yq = y.clone();
    for v in yq.data.iter_mut() {
        *v += 0.05 * rng.normal() as f32;
    }
    let w = Matrix::from_vec(n, neurons, rng.uniform_vec(n * neurons, -1.0, 1.0));
    (LayerData::new(&y, &yq), w)
}

#[test]
fn parallel_layer_bit_identical_to_serial() {
    // 13 neurons: serial runs one LANES block + a 5-neuron tail, while the
    // parallel partitions cut at arbitrary offsets — every split must agree
    // to the last bit in q, errs AND rel_errs.
    let (data, w) = fixed_seed_layer(101, 24, 48, 13);
    let a = Alphabet::ternary(0.9);
    let serial = gpfq_layer(&data, &w, a);
    for workers in [2usize, 3, 5, 8, 32] {
        let par = gpfq_layer_parallel(&data, &w, a, workers);
        assert_eq!(serial.q.data, par.q.data, "q mismatch at workers={workers}");
        assert_eq!(serial.errs, par.errs, "errs mismatch at workers={workers}");
        assert_eq!(serial.rel_errs, par.rel_errs, "rel_errs mismatch at workers={workers}");
    }
}

#[test]
fn lane_and_tail_paths_agree_per_neuron() {
    // regression for the partition-dependence bug: a neuron must produce the
    // same (q, err) whether it lands in a full lane block (interleaved
    // kernel) or a tail block (per-neuron kernel).
    let (data, w) = fixed_seed_layer(102, 17, 40, LANES + 3);
    let a = Alphabet::new(0.8, 4);
    let blocked = gpfq_layer(&data, &w, a); // lane kernel for the first LANES neurons
    let mut u = vec![0.0f32; data.m()];
    for j in 0..w.cols {
        let wcol = w.col(j);
        let res = gpfq_neuron(&data, &wcol, a, &mut u); // always the scalar path
        assert_eq!(blocked.q.col(j), res.q, "q mismatch at neuron {j}");
        assert_eq!(blocked.errs[j], res.err, "err mismatch at neuron {j}");
    }
}

#[test]
fn every_block_partition_is_bit_identical() {
    // sweep block offsets directly: quantizing [0, n) must equal the
    // concatenation of [0, k) and [k, n) for every cut point k.
    let (data, w) = fixed_seed_layer(103, 12, 30, 11);
    let a = Alphabet::ternary(1.0);
    let whole = gpfq_layer_range(&data, &w, a, 0, w.cols);
    for k in 0..=w.cols {
        let lo = gpfq_layer_range(&data, &w, a, 0, k);
        let hi = gpfq_layer_range(&data, &w, a, k, w.cols);
        let mut q = Vec::new();
        for j in 0..k {
            q.extend(lo.q.col(j));
        }
        for j in 0..(w.cols - k) {
            q.extend(hi.q.col(j));
        }
        let mut whole_q = Vec::new();
        for j in 0..w.cols {
            whole_q.extend(whole.q.col(j));
        }
        assert_eq!(whole_q, q, "cut at {k}");
        let errs: Vec<f64> = lo.errs.iter().chain(&hi.errs).copied().collect();
        assert_eq!(whole.errs, errs, "errs cut at {k}");
        let rels: Vec<f64> = lo.rel_errs.iter().chain(&hi.rel_errs).copied().collect();
        assert_eq!(whole.rel_errs, rels, "rel_errs cut at {k}");
    }
}

#[test]
fn executor_bit_identical_across_workers_and_block_widths() {
    let (data, w) = fixed_seed_layer(104, 16, 36, 10);
    // executor takes raw activation matrices; rebuild them from the data
    let y = data.yt.transpose();
    let yq = data.yqt.transpose();
    let a = Alphabet::ternary(0.85);
    let serial = gpfq_layer(&data, &w, a);
    for block_b in [1usize, 3, 8, 64] {
        for workers in [1usize, 2, 8] {
            let ex = Executor { block_b, ..Executor::native(workers) };
            let (q, paths) = ex.gpfq_layer(&y, &yq, &w, a).unwrap();
            assert!(paths.iter().all(|&p| p == Path::Native));
            assert_eq!(
                serial.q.data, q.data,
                "executor mismatch at block_b={block_b} workers={workers}"
            );
        }
    }
}

#[test]
fn pipeline_quantized_network_bit_identical_across_worker_counts() {
    let net = mnist_mlp(7, 32, &[24, 16], 4);
    let mut rng = Pcg::seed(105);
    let x = Matrix::from_vec(40, 32, rng.normal_vec(40 * 32));
    let run = |workers: usize| {
        let cfg = PipelineConfig { workers, c_alpha: 2.5, ..Default::default() };
        let out = quantize_network(&net, &x, &cfg);
        out.network
            .layers
            .iter()
            .filter_map(|l| l.weights())
            .flat_map(|w| w.data.iter().copied())
            .collect::<Vec<f32>>()
    };
    let base = run(1);
    for workers in [2usize, 4, 8] {
        assert_eq!(base, run(workers), "pipeline diverged at workers={workers}");
    }
}

#[test]
fn scheduler_runs_jobs_concurrently() {
    // the worker pool must actually overlap jobs (scoped threads), not
    // degenerate into a serial drain: with 4 workers and jobs that wait to
    // observe a peer in flight, at least two must coexist.
    let cfg = SchedulerConfig { workers: 4, queue_cap: 8 };
    let inflight = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let out: Vec<usize> = run_jobs(cfg, (0..8).collect(), |_, j| {
        let cur = inflight.fetch_add(1, Ordering::SeqCst) + 1;
        peak.fetch_max(cur, Ordering::SeqCst);
        let t0 = Instant::now();
        while peak.load(Ordering::SeqCst) < 2 && t0.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(2));
        }
        inflight.fetch_sub(1, Ordering::SeqCst);
        Ok::<_, ()>(j)
    })
    .unwrap();
    assert_eq!(out, (0..8).collect::<Vec<_>>());
    assert!(
        peak.load(Ordering::SeqCst) >= 2,
        "scheduler never had two neuron-block jobs in flight at once"
    );
}
