//! Integration tests for the `gpfq lint` engine (`analysis` module): the
//! real repo must lint clean, every positive fixture must trip exactly its
//! own rule, every negative fixture must be silent, and the committed
//! `rust/oracles.lock` must agree with hashes recomputed from the live
//! sources — which also pins the Rust runner to the Python-generated
//! manifest byte-for-byte.

use std::path::{Path, PathBuf};

use gpfq::analysis::{manifest, run_lint, ALLOWLIST_PATH, MANIFEST_PATH};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf()
}

fn fixture(name: &str) -> PathBuf {
    repo_root().join("rust/tests/lint_fixtures").join(name)
}

#[test]
fn full_repo_lints_clean() {
    let report = run_lint(&repo_root());
    let msgs: Vec<String> = report
        .active
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message))
        .collect();
    assert!(report.ok(), "lint findings on the real repo:\n{}", msgs.join("\n"));
    assert!(
        report.stale_allowlist_lines.is_empty(),
        "stale {ALLOWLIST_PATH} entries at lines {:?}",
        report.stale_allowlist_lines
    );
    assert!(!report.allowed.is_empty(), "allowlist should be exercising");
}

#[test]
fn positive_fixtures_trip_their_rule() {
    for (case, rule) in [
        ("oracle_freeze_positive", "oracle-freeze"),
        ("panic_path_positive", "panic-path"),
        ("lock_discipline_positive", "lock-discipline"),
        ("float_determinism_positive", "float-determinism"),
        ("zero_dep_positive", "zero-dep"),
    ] {
        let report = run_lint(&fixture(case));
        assert!(!report.active.is_empty(), "{case}: expected findings, got none");
        for f in &report.active {
            assert_eq!(f.rule, rule, "{case}: unexpected rule {} ({})", f.rule, f.message);
        }
    }
}

#[test]
fn negative_fixtures_are_clean() {
    for case in [
        "oracle_freeze_negative",
        "panic_path_negative",
        "lock_discipline_negative",
        "float_determinism_negative",
        "zero_dep_negative",
    ] {
        let report = run_lint(&fixture(case));
        let msgs: Vec<String> = report
            .active
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message))
            .collect();
        assert!(report.ok(), "{case}:\n{}", msgs.join("\n"));
    }
}

#[test]
fn lock_positive_covers_all_three_shapes() {
    let report = run_lint(&fixture("lock_discipline_positive"));
    let all: String =
        report.active.iter().map(|f| f.message.as_str()).collect::<Vec<_>>().join(" | ");
    assert!(all.contains("nested .lock()"));
    assert!(all.contains("condvar wait outside a predicate loop"));
    assert!(all.contains("I/O while lock guard"));
}

#[test]
fn oracle_manifest_matches_current_sources() {
    let root = repo_root();
    let pinned = manifest::parse_manifest(&root.join(MANIFEST_PATH)).unwrap();
    let current = manifest::compute_manifest(&root);
    assert_eq!(
        pinned, current,
        "{MANIFEST_PATH} disagrees with the frozen oracle sources; if the \
         oracle edit is intentional run `gpfq lint --fix-manifest` (or the \
         Python mirror) in the same change"
    );
    // every declared oracle item resolved to an actual source span
    assert_eq!(current.len(), manifest::ORACLE_ITEMS.len());
}

#[test]
fn one_char_tamper_is_caught() {
    // copy the pristine oracle fixture, flip one character in matmul_naive,
    // and the oracle-freeze rule must fire (the acceptance criterion)
    let dir = std::env::temp_dir().join(format!("gpfq_lint_tamper_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    copy_tree(&fixture("oracle_freeze_negative"), &dir);
    let target = dir.join("rust/src/nn/matrix.rs");
    let text = std::fs::read_to_string(&target).unwrap();
    assert!(text.contains("+="));
    std::fs::write(&target, text.replacen("+=", "-=", 1)).unwrap();
    let report = run_lint(&dir);
    assert_eq!(report.active.len(), 1, "expected exactly the drift finding");
    assert_eq!(report.active[0].rule, "oracle-freeze");
    assert!(report.active[0].message.contains("drifted"));
    std::fs::remove_dir_all(&dir).ok();
}

fn copy_tree(from: &Path, to: &Path) {
    std::fs::create_dir_all(to).unwrap();
    for entry in std::fs::read_dir(from).unwrap() {
        let entry = entry.unwrap();
        let dest = to.join(entry.file_name());
        if entry.path().is_dir() {
            copy_tree(&entry.path(), &dest);
        } else {
            std::fs::copy(entry.path(), &dest).unwrap();
        }
    }
}
