//! Walk-order backprop bit-parity.
//!
//! PR 2 taught the *quantization* engine to build each layer's walk-order
//! view (transposed activations / the im2col patch matrix built directly
//! transposed) exactly once and share it between the quantizer and the
//! forward GEMM.  The training path now makes the same im2col-once
//! argument: `forward_train` caches the walk view, the forward GEMM runs
//! through `Matrix::matmul_tn` (pinned bit-identical to
//! `transpose().matmul()`), and `backward` reads the cached view for the
//! weight gradients with **zero** transposed materializations.
//!
//! This file freezes the pre-walk gradient path verbatim (standard-layout
//! caches, `patches.transpose().matmul(dpre)` / `input.transpose()
//! .matmul(d)`) as a reference oracle — the same frozen-oracle pattern as
//! `coordinator::reference` — and pins that logits, every gradient, every
//! BN statistic and a full SGD step agree **bit for bit**.

use gpfq::data::rng::Pcg;
use gpfq::nn::activations::softmax_rows;
use gpfq::nn::batchnorm::BnCache;
use gpfq::nn::conv::{col2im, fold_output, im2col, unfold_output, ImgShape};
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, Layer, Network};
use gpfq::nn::pool::{maxpool_backward, maxpool_forward};
use gpfq::train::backprop::{backward, forward_train, Grad, SgdState};
use gpfq::train::softmax_ce;

// ---------------------------------------------------------------------------
// Frozen pre-walk reference path (PR 1–3 backprop, verbatim semantics):
// standard-layout caches, transposes materialized in the backward pass.
// ---------------------------------------------------------------------------

enum RefCache {
    Dense { input: Matrix, pre: Matrix },
    Conv { patches: Matrix, pre: Matrix, batch: usize },
    Pool { argmax: Vec<usize> },
    Bn(BnCache),
}

fn ref_forward_train(net: &mut Network, x: &Matrix) -> (Matrix, Vec<RefCache>) {
    let mut caches = Vec::with_capacity(net.layers.len());
    let mut h = x.clone();
    for layer in &mut net.layers {
        match layer {
            Layer::Dense { w, b, act } => {
                let mut pre = h.matmul(w);
                pre.add_row_vec(b);
                let mut out = pre.clone();
                act.apply(&mut out);
                caches.push(RefCache::Dense { input: h, pre });
                h = out;
            }
            Layer::Conv { k, b, kh, kw, stride, act, in_shape } => {
                let patches = im2col(&h, *in_shape, *kh, *kw, *stride);
                let mut pre = patches.matmul(k);
                pre.add_row_vec(b);
                let mut out = pre.clone();
                act.apply(&mut out);
                let batch = h.rows;
                caches.push(RefCache::Conv { patches, pre, batch });
                h = fold_output(out, batch);
            }
            Layer::MaxPool { size, in_shape } => {
                let (out, argmax, _) = maxpool_forward(&h, *in_shape, *size);
                caches.push(RefCache::Pool { argmax });
                h = out;
            }
            Layer::BatchNorm(bn) => {
                let (out, cache) = bn.forward_train(&h);
                caches.push(RefCache::Bn(cache));
                h = out;
            }
            other => panic!("reference training path supports float layers only, got {}", other.label()),
        }
    }
    (h, caches)
}

fn ref_backward(net: &Network, caches: &[RefCache], dlogits: Matrix) -> Vec<Grad> {
    let mut grads: Vec<Grad> = Vec::with_capacity(net.layers.len());
    let mut d = dlogits;
    for (layer, cache) in net.layers.iter().zip(caches).rev() {
        match (layer, cache) {
            (Layer::Dense { w, act, .. }, RefCache::Dense { input, pre }) => {
                act.backprop(pre, &mut d);
                let dw = input.transpose().matmul(&d);
                let mut db = vec![0.0f32; w.cols];
                for r in 0..d.rows {
                    for (c, v) in db.iter_mut().enumerate() {
                        *v += d.at(r, c);
                    }
                }
                let dx = d.matmul(&w.transpose());
                grads.push(Grad::Dense { dw, db });
                d = dx;
            }
            (
                Layer::Conv { k, kh, kw, stride, act, in_shape, .. },
                RefCache::Conv { patches, pre, batch },
            ) => {
                let mut dpre = unfold_output(&d, k.cols);
                act.backprop(pre, &mut dpre);
                let dk = patches.transpose().matmul(&dpre);
                let mut db = vec![0.0f32; k.cols];
                for r in 0..dpre.rows {
                    for (c, v) in db.iter_mut().enumerate() {
                        *v += dpre.at(r, c);
                    }
                }
                let dpatches = dpre.matmul(&k.transpose());
                let dx = col2im(&dpatches, *batch, *in_shape, *kh, *kw, *stride);
                grads.push(Grad::Conv { dk, db });
                d = dx;
            }
            (Layer::MaxPool { in_shape, .. }, RefCache::Pool { argmax }) => {
                d = maxpool_backward(&d, argmax, *in_shape);
                grads.push(Grad::Pool);
            }
            (Layer::BatchNorm(bn), RefCache::Bn(cache)) => {
                let mut dgamma = vec![0.0f32; bn.channels];
                let mut dbeta = vec![0.0f32; bn.channels];
                d = bn.backward(cache, &d, &mut dgamma, &mut dbeta);
                grads.push(Grad::Bn { dgamma, dbeta });
            }
            _ => unreachable!("cache/layer mismatch"),
        }
    }
    grads.reverse();
    grads
}

// ---------------------------------------------------------------------------

fn toy_batch(rng: &mut Pcg, n: usize, dim: usize, classes: usize) -> (Matrix, Matrix) {
    let x = Matrix::from_vec(n, dim, rng.normal_vec(n * dim));
    let mut y = Matrix::zeros(n, classes);
    for r in 0..n {
        *y.at_mut(r, rng.below(classes)) = 1.0;
    }
    (x, y)
}

fn assert_grads_identical(a: &[Grad], b: &[Grad], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: grad count");
    for (i, (ga, gb)) in a.iter().zip(b).enumerate() {
        match (ga, gb) {
            (Grad::Dense { dw: wa, db: ba }, Grad::Dense { dw: wb, db: bb })
            | (Grad::Conv { dk: wa, db: ba }, Grad::Conv { dk: wb, db: bb }) => {
                assert_eq!(wa.data, wb.data, "{tag}: layer {i} weight grad");
                assert_eq!(ba, bb, "{tag}: layer {i} bias grad");
            }
            (Grad::Pool, Grad::Pool) => {}
            (
                Grad::Bn { dgamma: ga_, dbeta: be_ },
                Grad::Bn { dgamma: gb_, dbeta: bb_ },
            ) => {
                assert_eq!(ga_, gb_, "{tag}: layer {i} dgamma");
                assert_eq!(be_, bb_, "{tag}: layer {i} dbeta");
            }
            _ => panic!("{tag}: layer {i} grad kind mismatch"),
        }
    }
}

fn assert_networks_identical(a: &Network, b: &Network, tag: &str) {
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        match (la, lb) {
            (Layer::Dense { w: wa, b: ba, .. }, Layer::Dense { w: wb, b: bb, .. })
            | (Layer::Conv { k: wa, b: ba, .. }, Layer::Conv { k: wb, b: bb, .. }) => {
                assert_eq!(wa.data, wb.data, "{tag}: layer {i} weights");
                assert_eq!(ba, bb, "{tag}: layer {i} bias");
            }
            (Layer::BatchNorm(na), Layer::BatchNorm(nb)) => {
                assert_eq!(na.gamma, nb.gamma, "{tag}: layer {i} gamma");
                assert_eq!(na.beta, nb.beta, "{tag}: layer {i} beta");
            }
            (Layer::MaxPool { .. }, Layer::MaxPool { .. }) => {}
            _ => panic!("{tag}: layer {i} kind mismatch"),
        }
    }
}

/// One full training step (forward → loss → backward → SGD) on both paths,
/// asserting bit-identity at every stage.
fn step_parity(mut net: Network, x: &Matrix, y: &Matrix, steps: usize, tag: &str) {
    let mut refnet = net.clone();
    let mut sgd = SgdState::new(&net, 0.05, 0.9);
    let mut ref_sgd = SgdState::new(&refnet, 0.05, 0.9);
    for step in 0..steps {
        let (logits, caches) = forward_train(&mut net, x);
        let (ref_logits, ref_caches) = ref_forward_train(&mut refnet, x);
        assert_eq!(logits.data, ref_logits.data, "{tag}: step {step} logits");
        let (loss, dlogits) = softmax_ce(&logits, y);
        let (ref_loss, ref_dlogits) = softmax_ce(&ref_logits, y);
        assert_eq!(loss, ref_loss, "{tag}: step {step} loss");
        let grads = backward(&net, &caches, dlogits);
        let ref_grads = ref_backward(&refnet, &ref_caches, ref_dlogits);
        assert_grads_identical(&grads, &ref_grads, &format!("{tag}: step {step}"));
        sgd.step(&mut net, &grads);
        ref_sgd.step(&mut refnet, &ref_grads);
        assert_networks_identical(&net, &refnet, &format!("{tag}: step {step}"));
    }
}

#[test]
fn dense_walk_backprop_bit_identical_to_reference() {
    let mut rng = Pcg::seed(41);
    let net = mnist_mlp(11, 12, &[10, 7], 4);
    let (x, y) = toy_batch(&mut rng, 9, 12, 4);
    step_parity(net, &x, &y, 4, "mlp");
}

#[test]
fn conv_pool_bn_walk_backprop_bit_identical_to_reference() {
    // cifar_cnn stacks conv, bn, conv, maxpool, bn, dense, bn, dense —
    // every Cache arm (walk conv, walk dense, pool, bn) is exercised
    let mut rng = Pcg::seed(42);
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = cifar_cnn(12, img, &[3], 10, 3);
    let (x, y) = toy_batch(&mut rng, 5, img.len(), 3);
    step_parity(net, &x, &y, 3, "cnn");
}

#[test]
fn softmax_probabilities_unchanged_by_walk_refactor() {
    // guard against accidental coupling: the loss path reads logits only,
    // and identical logits must produce identical probability rows
    let mut rng = Pcg::seed(43);
    let logits = Matrix::from_vec(4, 5, rng.normal_vec(20));
    let p = softmax_rows(&logits);
    for r in 0..4 {
        let s: f32 = (0..5).map(|c| p.at(r, c)).sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}
