//! Fixture: `unsafe` is banned crate-wide.

/// Reads a byte through a raw pointer — forbidden in this codebase.
pub fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}
