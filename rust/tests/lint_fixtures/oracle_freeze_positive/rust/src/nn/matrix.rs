//! Fixture copy of the frozen naive-matmul oracle (lint corpus only).

/// Minimal row-major matrix, just enough surface for the fixture.
pub struct Matrix {
    /// Row-major element storage.
    pub data: Vec<f32>,
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
}

impl Matrix {
    /// Frozen reference: naive i-k-j triple loop, fixed summation order.
    pub fn matmul_naive(&self, b: &Matrix) -> Matrix {
        let mut out = vec![0.0f32; self.rows * b.cols];
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a_ik = self.data[i * self.cols + k];
                for j in 0..b.cols {
                    out[i * b.cols + j] -= a_ik * b.data[k * b.cols + j];
                }
            }
        }
        Matrix { data: out, rows: self.rows, cols: b.cols }
    }
}
