//! Fixture: panic-prone request handling on the untrusted surface.

/// Parse the Content-Length header out of a raw request head.
pub fn content_length(head: &str) -> usize {
    let line = head
        .lines()
        .find(|l| l.starts_with("Content-Length:"))
        .unwrap();
    let value = line.split(':').nth(1).expect("header value");
    value.trim().parse().unwrap()
}

/// Return the first byte of the body — indexes without a bounds check.
pub fn first_body_byte(body: &[u8]) -> u8 {
    body[0]
}
