//! Fixture: the identical reductions are legal here — `nn/kernels.rs` is
//! where the frozen, reviewed summation trees live (float-exempt file).

/// Sum a residual vector with the iterator adapter.
pub fn residual_norm(u: &[f32]) -> f32 {
    u.iter().map(|x| x * x).sum::<f32>()
}

/// Hand-rolled accumulator loop.
pub fn residual_sum(u: &[f32]) -> f64 {
    let mut acc = 0.0;
    for x in u {
        acc += f64::from(*x);
    }
    acc
}
