//! Fixture: float reductions outside the frozen kernel files.

/// Sum a residual vector with the iterator adapter — the summation tree is
/// whatever the implementation picks, not a reviewed, frozen order.
pub fn residual_norm(u: &[f32]) -> f32 {
    u.iter().map(|x| x * x).sum::<f32>()
}

/// Hand-rolled accumulator loop, same problem.
pub fn residual_sum(u: &[f32]) -> f64 {
    let mut acc = 0.0;
    for x in u {
        acc += f64::from(*x);
    }
    acc
}
