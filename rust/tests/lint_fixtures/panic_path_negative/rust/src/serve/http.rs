//! Fixture: the same request handling written panic-free.

/// Parse the Content-Length header out of a raw request head.
pub fn content_length(head: &str) -> Option<usize> {
    let line = head.lines().find(|l| l.starts_with("Content-Length:"))?;
    let value = line.split(':').nth(1)?;
    value.trim().parse().ok()
}

/// Return the first byte of the body, if any.
pub fn first_body_byte(body: &[u8]) -> Option<u8> {
    body.first().copied()
}
