//! Fixture: a clean zero-dep crate root.

/// Reads a byte safely, if there is one.
pub fn peek(bytes: &[u8]) -> Option<u8> {
    bytes.first().copied()
}
