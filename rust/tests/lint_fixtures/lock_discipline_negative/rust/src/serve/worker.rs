//! Fixture: the same worker written with clean lock discipline.

use std::io::Write;
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};

/// Shared worker state behind two locks and a condvar.
pub struct Worker {
    /// Pending job queue.
    pub queue: Mutex<Vec<u32>>,
    /// Completed-job counter.
    pub done: Mutex<u32>,
    /// Signalled when the queue gains work.
    pub available: Condvar,
}

impl Worker {
    /// One lock at a time: read the queue length, release, then update.
    pub fn drain_into_done(&self) {
        let n = {
            let guard = match self.queue.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            guard.len() as u32
        };
        if let Ok(mut done) = self.done.lock() {
            *done += n;
        }
    }

    /// Condvar wait inside a predicate loop, tolerant of spurious wakeups.
    pub fn wait_for_work(&self) {
        let mut guard = match self.queue.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        while guard.is_empty() {
            guard = match self.available.wait(guard) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// Copy what the report needs, drop the guard, then touch the socket.
    pub fn report(&self, stream: &mut TcpStream) {
        let pending;
        {
            let guard = match self.queue.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            pending = guard.len();
        }
        stream.write_all(format!("{pending} pending\n").as_bytes()).ok();
    }
}
