//! Fixture: lock-discipline violations in a serve-side worker.

use std::io::Write;
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};

/// Shared worker state behind two locks and a condvar.
pub struct Worker {
    /// Pending job queue.
    pub queue: Mutex<Vec<u32>>,
    /// Completed-job counter.
    pub done: Mutex<u32>,
    /// Signalled when the queue gains work.
    pub available: Condvar,
}

impl Worker {
    /// Nested `.lock()` acquisitions in one expression: lock-order hazard.
    pub fn drain_into_done(&self) {
        *self.done.lock().unwrap() += self.queue.lock().unwrap().len() as u32;
    }

    /// Condvar wait with no predicate loop: spurious wakeups break it.
    pub fn wait_once(&self) {
        let guard = self.queue.lock().unwrap();
        let _guard = self.available.wait(guard).unwrap();
    }

    /// Socket write while the queue guard is still live.
    pub fn report(&self, stream: &mut TcpStream) {
        let guard = self.queue.lock().unwrap();
        stream.write_all(format!("{} pending\n", guard.len()).as_bytes()).ok();
    }
}
