//! Observability integration pins: deterministic span trees on a synthetic
//! clock, the disabled-path zero-work contract, and the full distributed
//! round trip — worker spans riding `UnitResult` back to the coordinator,
//! re-based onto its clock and merged into per-worker timeline lanes.
//!
//! Every test here mutates the process-global obs state (the installed
//! recorder, the enabled flag, the trace id, the foreign-span store), so
//! the whole binary serializes on one mutex and each test starts from a
//! drained, disabled recorder.

use std::net::{SocketAddr, TcpListener};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

use gpfq::coordinator::{
    dist_sweep_trials, run_worker, DistConfig, Method, SweepConfig, TrialSet, WorkerFault,
};
use gpfq::data::synth::{generate, SynthSpec};
use gpfq::data::Dataset;
use gpfq::nn::conv::ImgShape;
use gpfq::nn::network::{mnist_mlp, Network};
use gpfq::obs::{self, ManualClock, Recorder, SpanKind, WallClock, DEFAULT_SPAN_CAP};
use gpfq::train::{train, TrainConfig};

/// One lock for the whole binary: obs state is process-global.
static TEST_GUARD: Mutex<()> = Mutex::new(());

/// Take the serial lock and reset every piece of global obs state so the
/// test observes only its own spans.
fn serial() -> MutexGuard<'static, ()> {
    let guard = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
    obs::disable();
    let _ = obs::take_spans();
    let _ = obs::take_foreign();
    obs::set_trace_id(0);
    guard
}

// ---------------------------------------------------------------------------
// deterministic span trees (ManualClock)
// ---------------------------------------------------------------------------

/// The RAII nesting contract, byte-exact on a synthetic clock: parents via
/// the thread-local cell, durations from clock deltas, completion-order
/// draining, instant events parented under the innermost live span.
#[test]
fn span_tree_nests_and_times_deterministically() {
    let _serial = serial();
    let clock = Arc::new(ManualClock::new(1_000));
    obs::install_recorder(Arc::new(Recorder::new(1024, clock.clone())));
    obs::enable();

    let (request_id, batch_id, gemm_id) = {
        let request = obs::span("serve.request").field("bytes", 42);
        let request_id = request.id();
        assert!(request.is_active() && request_id > 0);
        clock.advance(5);
        let (batch_id, gemm_id) = {
            let batch = obs::span_with("serve.batch", || vec![("batch_size", 8)]);
            let batch_id = batch.id();
            clock.advance(7);
            let gemm_id = {
                let gemm = obs::span("serve.gemm");
                clock.advance(3);
                gemm.id()
            };
            obs::event("serve.flush", &[("rows", 8)]);
            clock.advance(2);
            (batch_id, gemm_id)
        };
        clock.advance(4);
        (request_id, batch_id, gemm_id)
    };
    obs::disable();

    let spans = obs::take_spans();
    // drop order: gemm, flush event, batch, request
    assert_eq!(spans.len(), 4, "exactly the four records above");
    let by_name = |n: &str| spans.iter().find(|s| s.name == n).expect("span recorded");

    let request = by_name("serve.request");
    assert_eq!((request.id, request.parent), (request_id, 0));
    assert_eq!((request.start_us, request.dur_us), (1_000, 21));
    assert_eq!(request.fields, vec![("bytes", 42)]);
    assert_eq!(request.kind, SpanKind::Complete);

    let batch = by_name("serve.batch");
    assert_eq!((batch.id, batch.parent), (batch_id, request_id));
    assert_eq!((batch.start_us, batch.dur_us), (1_005, 12));
    assert_eq!(batch.fields, vec![("batch_size", 8)]);

    let gemm = by_name("serve.gemm");
    assert_eq!((gemm.id, gemm.parent), (gemm_id, batch_id));
    assert_eq!((gemm.start_us, gemm.dur_us), (1_012, 3));

    let flush = by_name("serve.flush");
    assert_eq!(flush.parent, batch_id, "instant parents under the live span");
    assert_eq!((flush.start_us, flush.dur_us), (1_015, 0));
    assert_eq!(flush.kind, SpanKind::Instant);

    // completion order is drain order
    let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
    assert_eq!(names, vec!["serve.gemm", "serve.flush", "serve.batch", "serve.request"]);
}

/// `span_under` roots a span beneath an explicit (possibly cross-process)
/// parent id while leaving the thread-local nesting cell untouched for
/// siblings opened after it.
#[test]
fn span_under_attaches_to_the_explicit_parent() {
    let _serial = serial();
    let clock = Arc::new(ManualClock::new(0));
    obs::install_recorder(Arc::new(Recorder::new(64, clock.clone())));
    obs::enable();

    let wire_parent = 0xBEEF; // "coordinator-side" id off the trace header
    {
        let unit = obs::span_under("dist.unit", wire_parent);
        let unit_id = unit.id();
        clock.advance(10);
        {
            let _score = obs::span("sweep.score");
            clock.advance(1);
        }
        drop(unit);
        let spans = obs::take_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[1].parent, wire_parent, "explicit parent wins");
        assert_eq!(spans[0].parent, unit_id, "children still nest locally");
    }
    obs::disable();
}

// ---------------------------------------------------------------------------
// disabled path: zero work
// ---------------------------------------------------------------------------

/// With tracing off, guards are inert: ids are 0, `span_with` never invokes
/// its field closure, events and explicit records vanish, and nothing
/// reaches the ring — the contract that keeps instrumented hot loops at one
/// relaxed atomic load.
#[test]
fn disabled_tracing_does_no_work() {
    let _serial = serial();
    obs::install_recorder(Arc::new(Recorder::new(64, Arc::new(ManualClock::new(0)))));
    // NOT enabled
    let mut closure_ran = false;
    {
        let g = obs::span_with("quantize.layer", || {
            closure_ran = true;
            vec![("layer", 3)]
        });
        assert!(!g.is_active());
        assert_eq!(g.id(), 0, "inactive guards have the sentinel id");
        let g = g.field("rows", 128); // builder stays a no-op
        assert!(!g.is_active());
    }
    {
        let _child = obs::span("sweep.chunk");
        obs::event("dist.receipt_done", &[("unit", 1)]);
    }
    obs::record_span("serve.queue_wait", 5, 9, &[("jobs", 2)]);
    assert!(!closure_ran, "span_with must not evaluate fields when disabled");
    assert!(obs::take_spans().is_empty(), "nothing may reach the ring");
    assert_eq!(obs::dropped_spans(), 0);
}

// ---------------------------------------------------------------------------
// distributed round trip: worker spans merge into coordinator lanes
// ---------------------------------------------------------------------------

const N_QUANT: usize = 40;
const N_TRIALS: usize = 1;
const TRIAL_SEED: u64 = 7;

fn trained_mlp() -> (Network, Dataset, Dataset) {
    let spec = SynthSpec {
        classes: 3,
        shape: ImgShape { h: 8, w: 8, c: 1 },
        blobs: 4,
        noise: 0.15,
        max_shift: 1,
        seed: 21,
    };
    let tr = generate(&spec, 160, 0, false);
    let te = generate(&spec, 80, 1, false);
    let mut net = mnist_mlp(2, 64, &[24], 3);
    train(
        &mut net,
        &tr,
        &TrainConfig { epochs: 3, batch: 32, lr: 0.05, momentum: 0.9, seed: 2, verbose: false },
    );
    (net, tr, te)
}

fn spawn_worker(
    net: &Network,
    tr: &Dataset,
    te: &Dataset,
    cfg: &SweepConfig,
) -> (SocketAddr, JoinHandle<usize>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (net, tr, te, cfg) = (net.clone(), tr.clone(), te.clone(), cfg.clone());
    let handle = std::thread::spawn(move || {
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        run_worker(listener, &net, &trials, &te, &cfg, WorkerFault::default())
            .expect("worker serves")
    });
    (addr, handle)
}

/// The tentpole dist pin: with tracing on, each worker's `dist.unit` span
/// tree rides its `UnitResult` back, gets re-based onto the coordinator
/// clock, tagged with lane `1 + worker`, and parents under the
/// coordinator's `dist.drive_unit` span stamped on the `x-gpfq-trace`
/// header — while the merged artifact still matches the traced run's own
/// receipts (the parity pin itself lives in test_dist_sweep.rs; here the
/// workers are threads sharing one recorder, the worst-case topology for
/// span attribution).
#[test]
fn dist_round_trip_merges_worker_spans_into_lanes() {
    let _serial = serial();
    let (net, tr, te) = trained_mlp();
    let cfg = SweepConfig {
        levels: vec![3],
        c_alphas: vec![2.0, 4.0],
        methods: vec![Method::Gpfq],
        fc_only: false,
        topk: false,
        workers: 2,
        chunk_cells: Some(1), // 2 cells / chunk 1 = 2 units
    };
    let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
    let n_units = N_TRIALS * 2;

    obs::install_recorder(Arc::new(Recorder::new(DEFAULT_SPAN_CAP, Arc::new(WallClock::new()))));
    obs::enable();
    obs::set_trace_id(0x00AB_CDEF);

    let spawned: Vec<_> = (0..2).map(|_| spawn_worker(&net, &tr, &te, &cfg)).collect();
    let dcfg = DistConfig::new(spawned.iter().map(|(a, _)| *a).collect());
    let out = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg).expect("traced sweep");
    for (_, handle) in spawned {
        handle.join().expect("worker exits after /shutdown");
    }
    obs::disable();
    let local = obs::take_spans();
    let foreign = obs::take_foreign();

    // coordinator side: one drive span + one done-receipt event per unit
    let drive_ids: Vec<u64> =
        local.iter().filter(|s| s.name == "dist.drive_unit").map(|s| s.id).collect();
    assert_eq!(drive_ids.len(), n_units, "one dist.drive_unit per unit");
    let receipts = local
        .iter()
        .filter(|s| s.name == "dist.receipt_done" && s.kind == SpanKind::Instant)
        .count();
    assert_eq!(receipts, n_units, "one dist.receipt_done event per unit");

    // worker side, after the merge
    assert!(!foreign.is_empty(), "worker spans must ride UnitResult back");
    for s in &foreign {
        assert_eq!(s.trace, 0x00AB_CDEF, "{}: workers adopt the wire trace id", s.name);
        assert!(
            (1..=2).contains(&s.lane),
            "{}: merged spans sit on worker lanes, got {}",
            s.name,
            s.lane
        );
    }
    let units: Vec<_> = foreign.iter().filter(|s| s.name == "dist.unit").collect();
    assert_eq!(units.len(), n_units, "each unit roots one dist.unit span");
    for u in &units {
        assert!(
            drive_ids.contains(&u.parent),
            "dist.unit parents under a coordinator dist.drive_unit span (got {})",
            u.parent
        );
        assert!(!u.instant && u.dur_us > 0, "dist.unit is a real duration");
    }
    assert!(
        foreign.iter().any(|s| s.name == "sweep.score"),
        "worker-side child spans survive the merge"
    );
    // both receipts and the merged artifact agree the run was healthy
    assert_eq!(out.requeues, 0, "tracing must not perturb scheduling");
    assert_eq!(out.worker_units.iter().sum::<usize>(), n_units);

    // the exporter renders one timeline: coordinator lane 0 plus a named
    // lane per worker, every worker event on its own lane
    let doc = obs::chrome_trace(&local, &foreign, 0x00AB_CDEF, 0);
    let parsed = gpfq::util::json::parse(&doc.to_string()).expect("valid JSON");
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents");
    let lane_of = |e: &gpfq::util::json::Json| e.get("pid").as_f64().map(|p| p as u64);
    let mut lanes: Vec<u64> = events.iter().filter_map(lane_of).collect();
    lanes.sort_unstable();
    lanes.dedup();
    // which workers served units is a scheduling race; the document must
    // carry lane 0 plus exactly the lanes the merged spans landed on
    let mut expected: Vec<u64> = foreign.iter().map(|s| s.lane).collect();
    expected.push(0);
    expected.sort_unstable();
    expected.dedup();
    assert_eq!(lanes, expected, "coordinator lane + every merged worker lane");
    assert!(lanes.len() >= 2, "at least one worker lane in the timeline");
    assert_eq!(
        parsed.get("otherData").get("trace_id").as_str(),
        Some("0000000000abcdef"),
        "the document is stamped with the shared trace id"
    );
}
