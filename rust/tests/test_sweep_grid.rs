//! Sweep-grid engine guarantees, pinned hard:
//!
//! 1. **Grid parity** — the shared-session [`SweepSession`] produces, for
//!    every (method × M × C_alpha) cell, a quantized network and top-1/top-5
//!    scores *bit-identical* to an independent `quantize_network` run with
//!    that cell's config, across worker counts and under `fc_only` (the
//!    PR-1/PR-2 determinism contract extended to the grid engine).
//! 2. **Analog economy** — the analog stream advances and its walk-order
//!    views (im2col for conv layers) are built **once per layer per sweep**,
//!    never × cells, measured both through the engine's own counters and the
//!    process-wide im2col invocation counter under a serial lock (the same
//!    counted-pin pattern as PR 2's 3-vs-8 im2col test).
//! 3. **Trial/chunk/fusion invariants** (the memory-bounded multi-trial
//!    engine): a chunked multi-trial sweep is per-cell bit-identical on its
//!    trial 0 — raw weights and top-1/top-5 — to the unchunked single-trial
//!    engine, across worker counts and chunk sizes; trial RNG streams are
//!    deterministic and non-overlapping whatever the worker count; fused
//!    quantize→score graphs return exactly what the two-phase path returns,
//!    and the worker pool is **never re-seeded between the quantize and
//!    score phases** (one fused fan-out per chunk, pinned through the
//!    process-global pool-seeding counter); analog im2col scales with the
//!    trial count, never the cell count.
//!
//! The lock exists because `cargo test` runs tests of one binary
//! concurrently and the im2col / pool-seeding counters are process-global:
//! **every** test in this file holds it, so counter deltas are exact.

use std::sync::Mutex;

use gpfq::coordinator::pipeline::{quantize_network, Method};
use gpfq::coordinator::scheduler::pool_seedings;
use gpfq::coordinator::sweep::{
    sweep, sweep_trials, SweepCell, SweepConfig, SweepSession,
};
use gpfq::coordinator::TrialSet;
use gpfq::data::rng::Pcg;
use gpfq::data::synth::{generate, SynthSpec};
use gpfq::eval::metrics::{accuracy, topk_accuracy};
use gpfq::nn::conv::{im2col_invocations, ImgShape};
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, vgg_like, Network};
use gpfq::train::{train, TrainConfig};

static SERIAL: Mutex<()> = Mutex::new(());

fn rand_input(seed: u64, rows: usize, cols: usize) -> Matrix {
    let mut rng = Pcg::seed(seed);
    Matrix::from_vec(rows, cols, rng.normal_vec(rows * cols))
}

fn trained_mlp() -> (Network, gpfq::data::Dataset, gpfq::data::Dataset) {
    let spec = SynthSpec {
        classes: 4,
        shape: ImgShape { h: 8, w: 8, c: 1 },
        blobs: 4,
        noise: 0.15,
        max_shift: 1,
        seed: 31,
    };
    let tr = generate(&spec, 260, 0, false);
    let te = generate(&spec, 130, 1, false);
    let mut net = mnist_mlp(3, 64, &[40, 20], 4);
    train(
        &mut net,
        &tr,
        &TrainConfig { epochs: 8, batch: 32, lr: 0.05, momentum: 0.9, seed: 3, verbose: false },
    );
    (net, tr, te)
}

/// Assert two networks agree bit for bit in every quantizable weight.
fn assert_weights_identical(a: &Network, b: &Network, tag: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{tag}: layer count");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        match (la.weights(), lb.weights()) {
            (Some(wa), Some(wb)) => assert_eq!(wa.data, wb.data, "{tag}: layer {i} weights"),
            (None, None) => {}
            _ => panic!("{tag}: layer {i} kind mismatch"),
        }
    }
}

#[test]
fn grid_parity_top1_top5_across_worker_counts() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let x = tr.x.rows_slice(0, 120);
    let grid = SweepConfig {
        levels: vec![3, 16],
        c_alphas: vec![2.0, 4.0],
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: false,
        topk: true,
        workers: 1,
        chunk_cells: None,
    };
    let base = sweep(&net, &x, &te, &grid);
    assert_eq!(base.points.len(), 8);
    // every cell's scores are bit-identical to an independent per-cell run
    for p in &base.points {
        let cell = SweepCell::new(p.method, p.levels, p.c_alpha_requested);
        assert_eq!(cell.c_alpha, p.c_alpha_f32());
        let single = quantize_network(&net, &x, &cell.pipeline_config(false, 2));
        let top1 = accuracy(&single.network, &te);
        let top5 = topk_accuracy(&single.network, &te, 5);
        assert_eq!(p.top1, top1, "cell {:?}/M{}/C{}", p.method, p.levels, p.c_alpha);
        assert_eq!(p.top5, top5, "cell {:?}/M{}/C{}", p.method, p.levels, p.c_alpha);
    }
    // and the grid is deterministic across worker counts
    for workers in [2usize, 4] {
        let res = sweep(&net, &x, &te, &SweepConfig { workers, ..grid.clone() });
        for (a, b) in res.points.iter().zip(&base.points) {
            assert_eq!(a.top1, b.top1, "workers={workers}");
            assert_eq!(a.top5, b.top5, "workers={workers}");
            assert_eq!(a.c_alpha, b.c_alpha, "workers={workers}");
        }
    }
}

#[test]
fn grid_parity_fc_only_networks_bit_identical() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let net = vgg_like(54, img, &[3], &[24, 12], 3); // conv, mp, dense, bn, dense, bn, dense
    let x = rand_input(17, 6, img.len());
    let cells = vec![
        SweepCell::new(Method::Gpfq, 3, 2.0),
        SweepCell::new(Method::Gpfq, 3, 4.0),
        SweepCell::new(Method::Msq, 16, 3.0),
    ];
    for workers in [1usize, 4] {
        let outcome =
            SweepSession::new(&net, &x, cells.clone(), true, workers).run().unwrap();
        for (cell, qnet, _) in &outcome.networks {
            let single = quantize_network(&net, &x, &cell.pipeline_config(true, workers));
            let tag =
                format!("fc_only {:?}/M{}/C{} w={workers}", cell.method, cell.levels, cell.c_alpha);
            assert_weights_identical(qnet, &single.network, &tag);
        }
        // fc_only: 3 dense quantization points, conv crossed plain
        assert_eq!(outcome.stats.analog_views, 3);
    }
}

#[test]
fn sweep_builds_analog_views_once_per_layer_not_per_cell() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    // layers: conv, bn, conv, mp, bn, dense, bn, dense — 4 quantization points
    let net = cifar_cnn(55, img, &[3], 12, 3);
    let x = rand_input(18, 6, img.len());
    let cells: Vec<SweepCell> = [1.5f64, 2.0, 3.0, 4.0]
        .iter()
        .map(|&c| SweepCell::new(Method::Gpfq, 3, c))
        .collect();
    let n_cells = cells.len();

    let before = im2col_invocations();
    let outcome = SweepSession::new(&net, &x, cells.clone(), false, 2).run().unwrap();
    let sweep_calls = im2col_invocations() - before;

    // analog side never scales with the cell count:
    //   conv #1 is the first quantization point — every cell still shares
    //   the analog prefix, so ONE patch build serves the whole grid; conv #2
    //   runs after divergence: 1 analog build + one per cell.
    assert_eq!(
        sweep_calls,
        2 + n_cells,
        "sweep im2col must be analog-once-per-layer plus one per diverged cell"
    );
    assert_eq!(outcome.stats.analog_views, 4, "one analog view per quantization point");
    // layers 0..=6 crossed once each; the advance at the last quantization
    // point (layer 7) is skipped because nothing reads the streams after it
    assert_eq!(outcome.stats.analog_advances, 7, "layers crossed once, not x cells");
    // diverged cells build their own views at the 3 post-divergence points
    assert_eq!(outcome.stats.cell_views, 3 * n_cells);

    // the per-cell baseline the engine replaces: each independent engine run
    // costs 3 im2cols (PR 2's pin), so the grid costs 3 x cells
    let before = im2col_invocations();
    for cell in &cells {
        let single = quantize_network(&net, &x, &cell.pipeline_config(false, 2));
        let (_, qnet, _) = &outcome.networks[outcome
            .networks
            .iter()
            .position(|(c, _, _)| c == cell)
            .unwrap()];
        assert_weights_identical(qnet, &single.network, &format!("cnn C{}", cell.c_alpha));
    }
    let per_cell_calls = im2col_invocations() - before;
    assert_eq!(per_cell_calls, 3 * n_cells, "per-cell baseline im2col count changed");
    assert!(sweep_calls < per_cell_calls, "shared session must do strictly less im2col work");

    // analog counters are independent of the cell count: a 1-cell session
    // reports the same analog numbers as the 4-cell session above
    let one = SweepSession::new(&net, &x, cells[..1].to_vec(), false, 2).run().unwrap();
    assert_eq!(one.stats.analog_views, outcome.stats.analog_views);
    assert_eq!(one.stats.analog_advances, outcome.stats.analog_advances);
}

#[test]
fn msq_cells_are_data_free_and_do_zero_stream_work() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let net = cifar_cnn(57, img, &[3], 12, 3);
    let x = rand_input(20, 6, img.len());
    let cells: Vec<SweepCell> =
        (2..=4).map(|i| SweepCell::new(Method::Msq, 3, i as f64)).collect();
    let before = im2col_invocations();
    let outcome = SweepSession::new(&net, &x, cells.clone(), false, 2).run().unwrap();
    // analog side only: one walk view per conv quantization point; MSQ cells
    // never build views, never diverge, never advance a stream
    assert_eq!(im2col_invocations() - before, 2);
    assert_eq!(outcome.stats.cell_views, 0);
    for (cell, qnet, _) in &outcome.networks {
        let single = quantize_network(&net, &x, &cell.pipeline_config(false, 1));
        assert_weights_identical(qnet, &single.network, &format!("msq C{}", cell.c_alpha));
    }
}

#[test]
fn fc_only_sweep_crosses_shared_conv_once_for_all_cells() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = vgg_like(56, img, &[2], &[12], 3); // conv, mp, dense, bn, dense
    let x = rand_input(19, 5, img.len());
    let cells: Vec<SweepCell> =
        (1..=3).map(|i| SweepCell::new(Method::Gpfq, 3, i as f64)).collect();
    let before = im2col_invocations();
    let outcome = SweepSession::new(&net, &x, cells.clone(), true, 2).run().unwrap();
    // the unquantized conv layer is crossed while every stream still shares
    // the analog prefix: exactly ONE forward im2col for the whole grid
    assert_eq!(im2col_invocations() - before, 1);
    assert_eq!(outcome.stats.analog_views, 2, "two dense quantization points");

    // per-cell runs pay that conv im2col once each
    let before = im2col_invocations();
    for cell in &cells {
        let _ = quantize_network(&net, &x, &cell.pipeline_config(true, 1));
    }
    assert_eq!(im2col_invocations() - before, cells.len());
}

#[test]
fn sweep_function_reports_shared_seconds_and_grid_order() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let x = tr.x.rows_slice(0, 80);
    let cfg = SweepConfig {
        levels: vec![3],
        c_alphas: vec![2.0, 3.0],
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: false,
        workers: 2,
        topk: false,
        chunk_cells: None,
    };
    let res = sweep(&net, &x, &te, &cfg);
    assert_eq!(res.points.len(), 4);
    // canonical grid order: method-major, then M, then C_alpha
    let want: Vec<(Method, f64)> = vec![
        (Method::Gpfq, 2.0),
        (Method::Gpfq, 3.0),
        (Method::Msq, 2.0),
        (Method::Msq, 3.0),
    ];
    for (p, (m, c)) in res.points.iter().zip(&want) {
        assert_eq!(p.method, *m);
        assert_eq!(p.c_alpha_requested, *c);
    }
    assert!(res.shared_seconds >= 0.0);
    assert!(res.points.iter().all(|p| p.seconds >= 0.0));
}

/// Acceptance pin: a chunked + multi-trial sweep is per-cell bit-identical
/// — raw weights and top-1/top-5 — to the unchunked single-trial engine on
/// its trial 0, across worker counts and chunk sizes.
#[test]
fn chunked_multi_trial_trial0_bit_identical_to_unchunked_single_trial() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let trials = TrialSet::draw(&tr.x, 100, 3, 17);
    let grid = SweepConfig {
        levels: vec![3],
        c_alphas: vec![2.0, 3.0, 4.0],
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: false,
        topk: true,
        workers: 2,
        chunk_cells: None,
    };
    // the PR 3 engine: single trial (the pool prefix), whole grid resident
    let base = sweep(&net, &trials.sample_set(0), &te, &grid);
    assert_eq!(base.points.len(), 6);
    for chunk in [1usize, 2, 6] {
        for workers in [1usize, 4] {
            let cfg = SweepConfig { chunk_cells: Some(chunk), workers, ..grid.clone() };
            let res = sweep_trials(&net, &trials, &te, &cfg);
            assert_eq!(res.trials, 3);
            assert_eq!(res.chunk_cells, chunk);
            for (p, b) in res.points.iter().zip(&base.points) {
                let tag = format!(
                    "chunk={chunk} workers={workers} cell {:?}/M{}/C{}",
                    p.method, p.levels, p.c_alpha_requested
                );
                assert_eq!(p.top1, b.top1, "{tag}: trial-0 top1");
                assert_eq!(p.top5, b.top5, "{tag}: trial-0 top5");
                assert_eq!(p.top1_trials.len(), 3, "{tag}");
                assert_eq!(p.top1_trials[0], p.top1, "{tag}: trial 0 leads the vector");
                assert_eq!(p.top5_trials[0], p.top5, "{tag}");
            }
        }
    }
    // raw weights: chunk-wise sessions on trial 0 equal independent
    // per-cell pipeline runs bit for bit (cells never read each other's
    // state, so chunk membership cannot change any cell's bits)
    let cells = grid.cells();
    for chunk in [1usize, 2] {
        for cc in cells.chunks(chunk) {
            let outcome =
                SweepSession::new(&net, &trials.sample_set(0), cc.to_vec(), false, 2)
                    .run()
                    .unwrap();
            for (cell, qnet, _) in &outcome.networks {
                let single =
                    quantize_network(&net, &trials.sample_set(0), &cell.pipeline_config(false, 1));
                assert_weights_identical(
                    qnet,
                    &single.network,
                    &format!("chunk={chunk} cell {cell:?}"),
                );
            }
        }
    }
}

/// Trial RNG streams are fixed at construction: deterministic, prefix-
/// stable in the trial count, distinct across trials — and the engine's
/// per-trial scores cannot depend on the worker count.
#[test]
fn trial_streams_deterministic_and_independent_of_workers() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let trials = TrialSet::draw(&tr.x, 60, 3, 9);
    let again = TrialSet::draw(&tr.x, 60, 3, 9);
    for t in 0..3 {
        assert_eq!(trials.sample_set(t).data, again.sample_set(t).data, "trial {t} draw");
    }
    assert_eq!(trials.sample_set(0).data, tr.x.rows_slice(0, 60).data, "trial 0 is the prefix");
    assert_ne!(trials.sample_set(1).data, trials.sample_set(2).data, "streams must differ");
    let wider = TrialSet::draw(&tr.x, 60, 5, 9);
    for t in 0..3 {
        assert_eq!(trials.sample_set(t).data, wider.sample_set(t).data, "prefix-stable in T");
    }

    let cfg = SweepConfig {
        levels: vec![3],
        c_alphas: vec![2.0, 4.0],
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: false,
        topk: false,
        workers: 1,
        chunk_cells: None,
    };
    let base = sweep_trials(&net, &trials, &te, &cfg);
    for workers in [2usize, 4] {
        let res = sweep_trials(&net, &trials, &te, &SweepConfig { workers, ..cfg.clone() });
        for (a, b) in res.points.iter().zip(&base.points) {
            assert_eq!(a.top1_trials, b.top1_trials, "workers={workers}: per-trial scores");
            assert_eq!(a.top1_stats, b.top1_stats, "workers={workers}: aggregates");
        }
    }

    // lazy-draw bit-parity with the eager path: materializing every set up
    // front (what TrialSet did before the lazy refactor) and sweeping each
    // set through the single-trial engine must reproduce the lazy trial
    // stream score-for-score, bit for bit
    let eager_sets: Vec<Matrix> =
        (0..trials.len()).map(|t| trials.sample_set(t).as_ref().clone()).collect();
    for (a, b) in
        TrialSet::draw(&tr.x, 60, 3, 9).sample_set(2).data.iter().zip(&eager_sets[2].data)
    {
        assert_eq!(a, b, "re-drawn lazy set must equal the eager copy");
    }
    for (t, x) in eager_sets.iter().enumerate() {
        let single = sweep(&net, x, &te, &cfg);
        for (p, b) in single.points.iter().zip(&base.points) {
            assert_eq!(
                p.top1, b.top1_trials[t],
                "trial {t} cell {:?}/C{}: eager-set sweep vs lazy trial stream",
                p.method, p.c_alpha_requested
            );
        }
    }
}

/// The fused quantize→score graph returns exactly what the two-phase path
/// (run the grid, then score every network) returns — same cells, same
/// scores, same engine counters, same measured peak.
#[test]
fn fused_scoring_parity_with_two_phase_path() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let x = tr.x.rows_slice(0, 80);
    let cells = vec![
        SweepCell::new(Method::Gpfq, 3, 2.0),
        SweepCell::new(Method::Gpfq, 16, 4.0),
        SweepCell::new(Method::Msq, 3, 3.0),
    ];
    let two_phase = SweepSession::new(&net, &x, cells.clone(), false, 2).run().unwrap();
    let te2 = te.clone();
    let fused = SweepSession::new(&net, &x, cells.clone(), false, 2)
        .run_scored(move |qnet| (accuracy(qnet, &te2), topk_accuracy(qnet, &te2, 5)))
        .unwrap();
    assert_eq!(fused.scored.len(), two_phase.networks.len());
    for ((ca, (t1, t5), _), (cb, qnet, _)) in fused.scored.iter().zip(&two_phase.networks) {
        assert_eq!(ca, cb, "grid order preserved through the chained jobs");
        assert_eq!(*t1, accuracy(qnet, &te), "cell {ca:?} top1");
        assert_eq!(*t5, topk_accuracy(qnet, &te, 5), "cell {ca:?} top5");
    }
    assert_eq!(fused.stats, two_phase.stats, "engine counters agree");
    assert_eq!(
        fused.peak_resident_bytes, two_phase.peak_resident_bytes,
        "the fusion changes scheduling, not residency"
    );
}

/// Acceptance pin: a chunk seeds the pool ONCE for its whole per-layer DAG
/// — every wave (stream advances, per-layer quantize fan-outs, the fused
/// quantize→score tail) rides the sweep-wide [`SweepPool`]'s single
/// long-lived seeding, and [`sweep_trials`] shares that one pool across
/// every chunk of every trial.  So a whole sweep — any chunking, any trial
/// count — pays exactly ONE seeding, and the scoring phase adds zero (the
/// unfused two-phase path pays one extra for its scoring fan-out).
#[test]
fn fused_graph_never_reseeds_pool_between_quantize_and_score() {
    let _guard = SERIAL.lock().unwrap();
    let (net, tr, te) = trained_mlp();
    let trials = TrialSet::draw(&tr.x, 80, 2, 7);
    let grid = SweepConfig {
        levels: vec![3],
        c_alphas: vec![1.5, 2.0, 3.0, 4.0],
        methods: vec![Method::Gpfq],
        fc_only: false,
        topk: false,
        workers: 2,
        chunk_cells: None,
    };
    // unchunked, single trial: one sweep-wide pool, every per-layer wave
    // and the fused scoring tail chained onto it
    let before = pool_seedings();
    let res = sweep(&net, &trials.sample_set(0), &te, &grid);
    assert_eq!(res.points.len(), 4);
    assert_eq!(
        pool_seedings() - before,
        1,
        "one seeding for the whole sweep, score phase chained — never re-seeded"
    );
    // chunked: chunks share the sweep-wide pool — still one seeding
    let before = pool_seedings();
    let res = sweep(
        &net,
        &trials.sample_set(0),
        &te,
        &SweepConfig { chunk_cells: Some(2), ..grid.clone() },
    );
    assert_eq!(res.chunk_cells, 2);
    assert_eq!(pool_seedings() - before, 1, "chunks share the pool: still one seeding");
    // trials multiply the schedule, never the seeding count
    let before = pool_seedings();
    let _ = sweep_trials(&net, &trials, &te, &SweepConfig { chunk_cells: Some(2), ..grid.clone() });
    assert_eq!(pool_seedings() - before, 1, "2 trials x 2 chunks: still one seeding");
    // counterfactual: the two-phase path (run, then score on a fresh pool)
    // pays one extra seeding for the scoring fan-out
    let before = pool_seedings();
    let outcome =
        SweepSession::new(&net, &trials.sample_set(0), grid.cells(), false, 2).run().unwrap();
    let _scores = gpfq::coordinator::run_jobs(
        gpfq::coordinator::SchedulerConfig::with_workers(2),
        outcome.networks,
        |_, (_, qnet, _)| Ok::<_, ()>(accuracy(&qnet, &te)),
    )
    .unwrap();
    assert_eq!(pool_seedings() - before, 2, "unfused: 1 session + 1 score seeding");
    // a serial sweep (workers <= 1) builds no pool at all
    let before = pool_seedings();
    let _ = sweep(&net, &trials.sample_set(0), &te, &SweepConfig { workers: 1, ..grid.clone() });
    assert_eq!(pool_seedings() - before, 0, "serial sweeps seed nothing");
}

/// Analog economy across trials: the analog stream is re-paid once per
/// trial — its im2col count is T × (per-sweep analog cost), **regardless of
/// the cell count** (MSQ cells are data-free; GPFQ adds exactly one
/// im2col per diverged cell per post-divergence conv point).
#[test]
fn analog_im2col_scales_with_trials_never_cells() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    // layers: conv, bn, conv, mp, bn, dense, bn, dense — 2 conv quantization
    // points (the dense points transpose, never im2col)
    let net = cifar_cnn(58, img, &[3], 12, 3);
    let pool = rand_input(23, 12, img.len());
    let trials = TrialSet::draw(&pool, 6, 2, 5);
    // MSQ-only grids: analog side only — 2 im2cols per trial, whatever the
    // cell count
    for n_cells in [1usize, 3] {
        let cells: Vec<SweepCell> =
            (0..n_cells).map(|i| SweepCell::new(Method::Msq, 3, 2.0 + i as f64)).collect();
        let before = im2col_invocations();
        for t in 0..trials.len() {
            let out = SweepSession::new(&net, &trials.sample_set(t), cells.clone(), false, 2)
                .run_scored(|qnet| qnet.weight_count())
                .unwrap();
            assert_eq!(out.scored.len(), n_cells);
        }
        assert_eq!(
            im2col_invocations() - before,
            2 * trials.len(),
            "msq grid, {n_cells} cells: analog im2col is per-trial, never per-cell"
        );
    }
    // GPFQ grids: T × (2 analog + one per diverged cell at the second conv)
    for n_cells in [1usize, 3] {
        let cells: Vec<SweepCell> =
            (0..n_cells).map(|i| SweepCell::new(Method::Gpfq, 3, 2.0 + i as f64)).collect();
        let before = im2col_invocations();
        for t in 0..trials.len() {
            let _ = SweepSession::new(&net, &trials.sample_set(t), cells.clone(), false, 2)
                .run_scored(|qnet| qnet.weight_count())
                .unwrap();
        }
        assert_eq!(
            im2col_invocations() - before,
            trials.len() * (2 + n_cells),
            "gpfq grid, {n_cells} cells: analog side never scales with cells"
        );
    }
}
