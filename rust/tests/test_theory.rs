//! Theory integration tests: Monte-Carlo verification of the paper's
//! geometric lemmas and dynamics claims (E11/E12).

use gpfq::data::rng::Pcg;
use gpfq::nn::matrix::{axpy, dot, norm_sq};
use gpfq::quant::alphabet::Alphabet;
use gpfq::testing::prop::{forall, prop_assert};

/// q_t as defined by Lemma 1 for the ternary alphabet (first layer).
fn q_of(w: f32, x: &[f32], u: &[f32]) -> f32 {
    let a = Alphabet::ternary(1.0);
    a.nearest(w + dot(x, u) / norm_sq(x))
}

#[test]
fn lemma9_level_sets_are_balls() {
    // For |w| < 1/2 the set {X : q=1} is the ball B(u/(1-2w), ||u||/(1-2w))
    // and {X : q=-1} is B(-u/(1+2w), ||u||/(1+2w)).  Monte-Carlo: membership
    // of random X must match the ball predicate exactly (ties measure-zero).
    forall("lemma 9 level sets", 300, |g| {
        let m = g.dim(8).max(2);
        let u: Vec<f32> = g.normal_vec(m);
        let w = g.f32_in(-0.45, 0.45);
        let x: Vec<f32> = g.normal_vec(m);
        let q = q_of(w, &x, &u);
        let unorm = norm_sq(&u).sqrt();
        // q = 1 ball
        let c1: Vec<f32> = u.iter().map(|v| v / (1.0 - 2.0 * w)).collect();
        let r1 = unorm / (1.0 - 2.0 * w);
        let d1: f32 = x.iter().zip(&c1).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let in_ball1 = d1 <= r1;
        // q = -1 ball
        let cm: Vec<f32> = u.iter().map(|v| -v / (1.0 + 2.0 * w)).collect();
        let rm = unorm / (1.0 + 2.0 * w);
        let dm: f32 = x.iter().zip(&cm).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let in_ballm = dm <= rm;
        let margin = 1e-3 * unorm.max(1.0);
        // skip near-boundary cases (float ties)
        if (d1 - r1).abs() < margin || (dm - rm).abs() < margin {
            return Ok(());
        }
        prop_assert(
            (q == 1.0) == in_ball1 && (q == -1.0) == in_ballm,
            format!("w={w} q={q} in_ball1={in_ball1} in_ballm={in_ballm}"),
        )
    });
}

#[test]
fn remark10_level_sets_complement_for_large_w() {
    // For w > 1/2 the q=1 region is the COMPLEMENT of the ball.
    forall("remark 10 complement", 200, |g| {
        let m = g.dim(6).max(2);
        let u: Vec<f32> = g.normal_vec(m);
        let w = g.f32_in(0.55, 0.95);
        let x: Vec<f32> = g.normal_vec(m);
        let q = q_of(w, &x, &u);
        let unorm = norm_sq(&u).sqrt();
        let c1: Vec<f32> = u.iter().map(|v| v / (1.0 - 2.0 * w)).collect(); // negative scale
        let r1 = unorm / (2.0 * w - 1.0);
        let d1: f32 = x.iter().zip(&c1).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let margin = 1e-3 * unorm.max(1.0);
        if (d1 - r1).abs() < margin {
            return Ok(());
        }
        // outside the ball ⇒ q = 1 region per Remark 10
        prop_assert(
            (q == 1.0) == (d1 > r1),
            format!("w={w} q={q} d1={d1} r1={r1}"),
        )
    });
}

#[test]
fn corollary13_increment_bound() {
    // Δ‖u_t‖² ≤ B/4 when ‖X_t‖² ≤ B, for every step of a random run.
    let mut rng = Pcg::seed(5);
    let m = 12;
    for _ in 0..20 {
        let mut u = vec![0.0f32; m];
        let mut prev = 0.0f32;
        for _ in 0..200 {
            let x: Vec<f32> = rng.normal_vec(m);
            let b = norm_sq(&x);
            let w = rng.uniform_in(-1.0, 1.0) as f32;
            let q = q_of(w, &x, &u);
            axpy(w - q, &x, &mut u);
            let cur = norm_sq(&u);
            assert!(
                cur - prev <= b / 4.0 + 1e-3 * b,
                "increment {} > B/4 = {}",
                cur - prev,
                b / 4.0
            );
            prev = cur;
        }
    }
}

#[test]
fn orthogonal_data_reduces_to_msq() {
    // Section 4: if X_t ⟂ u_{t-1} for all t, then q_t = Q(w_t) exactly and
    // ‖u_t‖² = Σ (w_j − q_j)² ‖X_j‖².  Standard basis with t < m realizes it.
    let mut rng = Pcg::seed(6);
    let m = 40;
    let a = Alphabet::ternary(1.0);
    let w: Vec<f32> = rng.uniform_vec(m, -1.0, 1.0);
    let mut u = vec![0.0f32; m];
    let mut expect = 0.0f64;
    for (t, &wt) in w.iter().enumerate() {
        let mut x = vec![0.0f32; m];
        x[t] = 1.0; // orthogonal to u (supported on untouched coords)
        let q = q_of(wt, &x, &u);
        assert_eq!(q, a.nearest(wt), "t={t}");
        axpy(wt - q, &x, &mut u);
        expect += ((wt - q) as f64).powi(2);
    }
    let got = norm_sq(&u) as f64;
    assert!((got - expect).abs() < 1e-6, "{got} vs {expect}");
}

#[test]
fn state_norm_sqrt_t_vs_bounded() {
    // E11 quantitative shape: orthogonal-data state grows ~ sqrt(t) while
    // Gaussian-data state stays flat in t.
    let mut rng = Pcg::seed(7);
    let m = 512;
    let w: Vec<f32> = rng.uniform_vec(m, -1.0, 1.0);
    // orthogonal construction: standard basis, t < m
    let mut u = vec![0.0f32; m];
    let mut norm_at = std::collections::BTreeMap::new();
    for (t, &wt) in w.iter().enumerate() {
        let mut x = vec![0.0f32; m];
        x[t] = 1.0;
        let q = q_of(wt, &x, &u);
        axpy(wt - q, &x, &mut u);
        if t + 1 == 64 || t + 1 == 256 {
            norm_at.insert(t + 1, norm_sq(&u).sqrt());
        }
    }
    let growth = norm_at[&256] / norm_at[&64];
    assert!(
        (1.5..3.0).contains(&growth),
        "orthogonal growth {growth} not ~ sqrt(4)=2"
    );

    // Gaussian data: norm at t=256 comparable to norm at t=64 (bounded)
    let mq = 32;
    let sigma = 1.0 / (mq as f64).sqrt();
    let mut u = vec![0.0f32; mq];
    let mut g64 = 0.0f32;
    let mut g256 = 0.0f32;
    for t in 0..256 {
        let x: Vec<f32> = (0..mq).map(|_| (rng.normal() * sigma) as f32).collect();
        let wt = rng.uniform_in(-1.0, 1.0) as f32;
        let q = q_of(wt, &x, &u);
        axpy(wt - q, &x, &mut u);
        if t + 1 == 64 {
            g64 = norm_sq(&u).sqrt();
        }
        if t + 1 == 256 {
            g256 = norm_sq(&u).sqrt();
        }
    }
    assert!(
        g256 < 2.0 * g64 + 1.0,
        "gaussian state grew {g64} -> {g256}; should be bounded"
    );

}
