//! Config + CLI integration: every shipped config parses and builds, and
//! the CLI dispatch layer handles the happy/sad paths.

use std::path::Path;

use gpfq::cli::args::Args;
use gpfq::cli::commands::{dispatch, make_datasets, resolve_spec};
use gpfq::config::{toml, ExperimentSpec};

fn repo_path(rel: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn args(v: &[&str]) -> Args {
    Args::parse(v.iter().map(|s| s.to_string()).collect()).unwrap()
}

#[test]
fn every_shipped_config_parses_and_builds() {
    let dir = repo_path("configs");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let doc = toml::parse_file(&path).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let spec = ExperimentSpec::from_doc(&doc)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let net = spec.build_network();
        assert!(net.weight_count() > 0, "{}", path.display());
        assert!(!spec.quant.c_alphas.is_empty());
        seen += 1;
    }
    assert!(seen >= 4, "expected >= 4 shipped configs, found {seen}");
}

#[test]
fn shipped_configs_match_paper_grids() {
    // cifar config must carry the Table 1 grid; imagenet must be fc-only
    let doc = toml::parse_file(&repo_path("configs/cifar.toml")).unwrap();
    let spec = ExperimentSpec::from_doc(&doc).unwrap();
    assert_eq!(spec.quant.levels, vec![3, 4, 8, 16]);
    assert_eq!(spec.quant.c_alphas, vec![2.0, 3.0, 4.0, 5.0, 6.0]);
    let doc = toml::parse_file(&repo_path("configs/imagenet.toml")).unwrap();
    let spec = ExperimentSpec::from_doc(&doc).unwrap();
    assert!(spec.quant.fc_only);
    assert_eq!(spec.quant.levels, vec![3]);
    let doc = toml::parse_file(&repo_path("configs/mnist.toml")).unwrap();
    let spec = ExperimentSpec::from_doc(&doc).unwrap();
    assert_eq!(spec.quant.c_alphas.len(), 10, "Fig 1a sweeps C_alpha 1..10");
}

#[test]
fn cli_resolves_config_files() {
    let cfg = repo_path("configs/mnist.toml");
    let a = args(&["quantize", "--config", cfg.to_str().unwrap(), "--epochs", "1"]);
    let spec = resolve_spec(&a).unwrap();
    assert_eq!(spec.name, "mnist_mlp");
    assert_eq!(spec.train.epochs, 1);
}

#[test]
fn cli_full_quantize_run_tiny() {
    // a real end-to-end CLI run, shrunk to seconds
    let cfg = repo_path("configs/mnist.toml");
    let a = args(&[
        "quantize",
        "--config",
        cfg.to_str().unwrap(),
        "--epochs",
        "1",
        "--quant-samples",
        "64",
        "--c-alpha",
        "3",
        "--workers",
        "2",
    ]);
    let mut spec = resolve_spec(&a).unwrap();
    spec.dataset.n_train = 200;
    spec.dataset.n_test = 80;
    // run the pieces the command runs (dispatch would re-resolve full sizes)
    let (tr, te) = make_datasets(&spec);
    assert_eq!(tr.len(), 200);
    assert_eq!(te.len(), 80);
    let mut net = spec.build_network();
    gpfq::train::train(&mut net, &tr, &spec.train);
    let out = gpfq::coordinator::pipeline::quantize_network(
        &net,
        &tr.x.rows_slice(0, 64),
        &gpfq::coordinator::pipeline::PipelineConfig { workers: 2, ..Default::default() },
    );
    assert_eq!(out.layer_reports.len(), 3);
}

#[test]
fn cli_error_paths() {
    assert!(dispatch(&args(&["bogus"])).is_err());
    assert!(resolve_spec(&args(&["train", "--preset", "nope"])).is_err());
    assert!(resolve_spec(&args(&["train", "--config", "/nonexistent.toml"])).is_err());
    let a = args(&["train", "--epochs", "NaN"]);
    assert!(resolve_spec(&a).is_err());
}

#[test]
fn cli_help_and_info_run() {
    assert!(dispatch(&args(&["help"])).is_ok());
    // info must work whether or not artifacts exist
    assert!(dispatch(&args(&["info"])).is_ok());
}
