//! Distributed sweep parity, pinned hard: sharding (trial × chunk) work
//! units across worker processes must be a pure scheduling change.  The
//! merged artifact — trial-0 scores, per-trial vectors, `TrialStats`,
//! best-cell selection, `peak_resident_bytes` — is compared **bit for
//! bit** (`f64::to_bits`) against in-process [`sweep_trials`] for 1, 2
//! and 4 workers; only wall-clock timing fields are exempt.  The workers
//! here are threads holding their own copies of everything (network,
//! trial recipe, test set), speaking the real loopback HTTP protocol —
//! the same `run_worker` the `gpfq sweep-worker` process runs.

use std::net::{SocketAddr, TcpListener};
use std::thread::JoinHandle;

use gpfq::coordinator::dist::sweep_fingerprint;
use gpfq::coordinator::sweep::TrialStats;
use gpfq::coordinator::{
    dist_sweep_trials, run_worker, sweep_trials, DistConfig, Method, SweepConfig, SweepResult,
    TrialSet, UnitOutcome, WorkerFault,
};
use gpfq::data::synth::{generate, SynthSpec};
use gpfq::data::Dataset;
use gpfq::nn::conv::ImgShape;
use gpfq::nn::network::{mnist_mlp, Network};
use gpfq::serve::HttpClient;
use gpfq::train::{train, TrainConfig};

/// The shared trial recipe — coordinator and every worker must agree on
/// it (the fingerprint handshake enforces that they do).
const N_QUANT: usize = 60;
const N_TRIALS: usize = 2;
const TRIAL_SEED: u64 = 7;

fn trained_mlp() -> (Network, Dataset, Dataset) {
    let spec = SynthSpec {
        classes: 3,
        shape: ImgShape { h: 8, w: 8, c: 1 },
        blobs: 4,
        noise: 0.15,
        max_shift: 1,
        seed: 21,
    };
    let tr = generate(&spec, 240, 0, false);
    let te = generate(&spec, 120, 1, false);
    let mut net = mnist_mlp(2, 64, &[32], 3);
    train(
        &mut net,
        &tr,
        &TrainConfig { epochs: 6, batch: 32, lr: 0.05, momentum: 0.9, seed: 2, verbose: false },
    );
    (net, tr, te)
}

fn grid() -> SweepConfig {
    SweepConfig {
        levels: vec![3],
        c_alphas: vec![2.0, 4.0],
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: false,
        topk: true,
        workers: 2,
        chunk_cells: Some(2),
    }
}

/// Spawn one worker "process" (a thread with its own copies of
/// everything) serving the given spec off an ephemeral loopback port.
fn spawn_worker(
    net: &Network,
    tr: &Dataset,
    te: &Dataset,
    cfg: &SweepConfig,
    fault: WorkerFault,
) -> (SocketAddr, JoinHandle<usize>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (net, tr, te, cfg) = (net.clone(), tr.clone(), te.clone(), cfg.clone());
    let handle = std::thread::spawn(move || {
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        run_worker(listener, &net, &trials, &te, &cfg, fault).expect("worker serves")
    });
    (addr, handle)
}

fn bits(x: f64, y: f64, what: &str) {
    assert_eq!(x.to_bits(), y.to_bits(), "{what}: {x} vs {y}");
}

fn stats_bits(a: &TrialStats, b: &TrialStats, what: &str) {
    bits(a.mean, b.mean, &format!("{what}.mean"));
    bits(a.std, b.std, &format!("{what}.std"));
    bits(a.min, b.min, &format!("{what}.min"));
    bits(a.max, b.max, &format!("{what}.max"));
}

/// Every bit-comparable field of the sweep artifact; wall-clock fields
/// (`shared_seconds`, per-cell `seconds`) are exempt by contract.
fn assert_bit_identical(a: &SweepResult, b: &SweepResult, tag: &str) {
    bits(a.analog_top1, b.analog_top1, &format!("{tag}: analog_top1"));
    bits(a.analog_top5, b.analog_top5, &format!("{tag}: analog_top5"));
    assert_eq!(a.trials, b.trials, "{tag}: trials");
    assert_eq!(a.chunk_cells, b.chunk_cells, "{tag}: chunk_cells");
    assert_eq!(a.peak_resident_bytes, b.peak_resident_bytes, "{tag}: peak_resident_bytes");
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (i, (p, q)) in a.points.iter().zip(&b.points).enumerate() {
        let what = format!("{tag}: cell {i}");
        assert_eq!(p.method, q.method, "{what}: method");
        assert_eq!(p.levels, q.levels, "{what}: levels");
        bits(p.c_alpha, q.c_alpha, &format!("{what}: c_alpha"));
        bits(p.c_alpha_requested, q.c_alpha_requested, &format!("{what}: c_alpha_requested"));
        bits(p.top1, q.top1, &format!("{what}: trial-0 top1"));
        bits(p.top5, q.top5, &format!("{what}: trial-0 top5"));
        assert_eq!(p.top1_trials.len(), q.top1_trials.len(), "{what}: trial vector");
        for (t, (x, y)) in p.top1_trials.iter().zip(&q.top1_trials).enumerate() {
            bits(*x, *y, &format!("{what}: top1 trial {t}"));
        }
        for (t, (x, y)) in p.top5_trials.iter().zip(&q.top5_trials).enumerate() {
            bits(*x, *y, &format!("{what}: top5 trial {t}"));
        }
        stats_bits(&p.top1_stats, &q.top1_stats, &format!("{what}: top1_stats"));
        stats_bits(&p.top5_stats, &q.top5_stats, &format!("{what}: top5_stats"));
    }
    for m in [Method::Gpfq, Method::Msq] {
        let pick = |r: &SweepResult| r.best(m).map(|p| (p.levels, p.c_alpha_requested.to_bits()));
        assert_eq!(pick(a), pick(b), "{tag}: best {m:?} cell");
    }
}

/// The tentpole acceptance pin: 1, 2 and 4 workers all merge to the
/// exact in-process artifact, with zero re-queues and every assignment
/// receipt `Done`.
#[test]
fn dist_sweep_bit_identical_to_in_process_for_1_2_4_workers() {
    let (net, tr, te) = trained_mlp();
    let cfg = grid();
    let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
    let baseline = sweep_trials(&net, &trials, &te, &cfg);
    let n_units = N_TRIALS * 2; // 4 cells / chunk 2 = 2 chunks per trial

    for n_workers in [1usize, 2, 4] {
        let spawned: Vec<_> =
            (0..n_workers).map(|_| spawn_worker(&net, &tr, &te, &cfg, WorkerFault::default())).collect();
        let dcfg = DistConfig::new(spawned.iter().map(|(a, _)| *a).collect());
        let out = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg)
            .expect("healthy distributed sweep");
        assert_bit_identical(&baseline, &out.result, &format!("{n_workers} workers"));
        assert_eq!(out.requeues, 0, "{n_workers} workers: healthy run never re-queues");
        assert_eq!(
            out.worker_units.iter().sum::<usize>(),
            n_units,
            "{n_workers} workers: every unit served exactly once"
        );
        assert_eq!(out.assignments.len(), n_units, "{n_workers} workers: one receipt per unit");
        assert!(
            out.assignments.iter().all(|a| a.outcome == UnitOutcome::Done),
            "{n_workers} workers: all receipts Done"
        );
        for (i, (_, handle)) in spawned.into_iter().enumerate() {
            let served = handle.join().expect("worker thread exits after /shutdown");
            assert_eq!(
                served, out.worker_units[i],
                "worker {i}: served count agrees with the coordinator's receipt"
            );
        }
    }
}

/// `shutdown_workers: false` (the CLI's `--dist-keep-workers`) must skip
/// the post-drain `/shutdown` POST: the workers stay up for the next
/// sweep, and the same addresses serve a second run bit-identically.
#[test]
fn keep_workers_skips_the_shutdown_post() {
    let (net, tr, te) = trained_mlp();
    let cfg = grid();
    let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
    let spawned: Vec<_> =
        (0..2).map(|_| spawn_worker(&net, &tr, &te, &cfg, WorkerFault::default())).collect();
    let dcfg = DistConfig {
        addrs: spawned.iter().map(|(a, _)| *a).collect(),
        shutdown_workers: false,
        ..DistConfig::default()
    };
    let first = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg).expect("first sweep");
    // the workers were NOT shut down: the same addresses serve a whole
    // second sweep (a fresh handshake + every unit), bit-identically
    let second = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg).expect("workers still up");
    assert_bit_identical(&first.result, &second.result, "reused workers");
    // now shut them down by hand; the threads exit with BOTH sweeps'
    // units on their ledger — proof the first drain left them serving
    let mut total_served = 0;
    for (addr, handle) in spawned {
        let mut client = HttpClient::connect(addr).expect("worker still accepting");
        let (status, _) = client.request("POST", "/shutdown", None).unwrap();
        assert_eq!(status, 200);
        total_served += handle.join().expect("worker exits only on explicit shutdown");
    }
    let n_units = N_TRIALS * 2;
    assert_eq!(total_served, 2 * n_units, "both sweeps' units served by the kept workers");
}

/// A worker whose spec drifted (different grid here) must refuse the
/// handshake and fail the sweep loudly — never silently merge foreign
/// numbers.
#[test]
fn fingerprint_mismatch_fails_the_handshake_loudly() {
    let (net, tr, te) = trained_mlp();
    let cfg = grid();
    let drifted = SweepConfig { c_alphas: vec![1.0, 3.0], ..cfg.clone() };
    assert_ne!(
        {
            let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
            sweep_fingerprint(&net, &trials, &cfg)
        },
        {
            let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
            sweep_fingerprint(&net, &trials, &drifted)
        },
        "the drifted grid must change the fingerprint"
    );
    let (addr, handle) = spawn_worker(&net, &tr, &te, &drifted, WorkerFault::default());
    let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
    let err = dist_sweep_trials(&net, &trials, &te, &cfg, &DistConfig::new(vec![addr]))
        .expect_err("drifted worker must fail the sweep");
    let msg = format!("{err:#}");
    assert!(msg.contains("fingerprint"), "error names the cause: {msg}");
    // the refusing worker keeps serving (it never got a unit); shut it
    // down by hand so the thread exits
    let mut client = HttpClient::connect(addr).unwrap();
    let (status, _) = client.request("POST", "/shutdown", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(handle.join().unwrap(), 0, "the drifted worker served nothing");
}
