//! Exactness pins for the packed-domain inference kernels (`nn::kernels`).
//!
//! The PR 6 contract: a packed network's forward pass is **bit-identical**
//! to the same network eagerly decoded back to f32 — on an MLP and on a
//! conv/pool/batchnorm CNN, for any batch sharding (worker counts 1/2/4
//! via `forward_sharded`), and straight off the `.gpfq` save→load path.
//! The per-GEMM argument (packed/tiled vs the frozen naive summation
//! tree) is property-tested in `test_properties.rs`; this file pins the
//! whole-network composition.

use std::sync::Arc;

use gpfq::coordinator::pipeline::{quantize_network, PipelineConfig};
use gpfq::coordinator::scheduler::WorkerPool;
use gpfq::data::rng::Pcg;
use gpfq::nn::conv::ImgShape;
use gpfq::nn::kernels::{
    forward_sharded, forward_sharded_on, pack_network, packed_layer_count, unpack_network,
};
use gpfq::nn::batchnorm::BatchNorm;
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, Layer, Network, NetworkBuilder, Shape};
use gpfq::nn::serialize::{hints_from_outcome, load_file, save_file};
use gpfq::nn::Activation;

fn assert_bits(a: &Matrix, b: &Matrix, tag: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{tag}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: element {i}: {x} vs {y}");
    }
}

/// Quantize `net` and return its (packed-resident, eagerly-decoded) twins.
fn packed_twins(net: &Network, x_quant: &Matrix) -> (Network, Network) {
    let out =
        quantize_network(net, x_quant, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let packed = pack_network(&out.network, &hints_from_outcome(&out));
    assert!(packed_layer_count(&packed) > 0, "quantized net should pack");
    let unpacked = unpack_network(&packed);
    assert_eq!(packed_layer_count(&unpacked), 0, "unpack must clear every packed layer");
    (packed, unpacked)
}

#[test]
fn mlp_packed_forward_bit_identical_across_worker_counts() {
    let mut rng = Pcg::seed(51);
    let net = mnist_mlp(11, 20, &[14, 9], 4);
    let xq = Matrix::from_vec(24, 20, rng.normal_vec(24 * 20));
    let (packed, unpacked) = packed_twins(&net, &xq);
    assert!(packed.summary().contains("pdense"), "{}", packed.summary());
    let x = Matrix::from_vec(13, 20, rng.normal_vec(13 * 20));
    let want = unpacked.forward(&x);
    for workers in [1usize, 2, 4] {
        let got = forward_sharded(&packed, &x, workers);
        assert_bits(&got, &want, &format!("mlp workers={workers}"));
    }
}

#[test]
fn cnn_packed_forward_bit_identical_across_worker_counts() {
    // conv + maxpool + batchnorm + dense all on the forward path; only the
    // conv/dense layers pack, the rest must compose around them unchanged
    let mut rng = Pcg::seed(52);
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = cifar_cnn(12, img, &[3], 10, 3);
    let xq = Matrix::from_vec(10, img.len(), rng.normal_vec(10 * img.len()));
    let (packed, unpacked) = packed_twins(&net, &xq);
    assert!(packed.summary().contains("pconv"), "{}", packed.summary());
    let x = Matrix::from_vec(9, img.len(), rng.normal_vec(9 * img.len()));
    let want = unpacked.forward(&x);
    for workers in [1usize, 2, 4] {
        let got = forward_sharded(&packed, &x, workers);
        assert_bits(&got, &want, &format!("cnn workers={workers}"));
    }
}

#[test]
fn pool_resident_sharded_forward_bit_identical_across_shard_counts() {
    // the serve path's variant: shards submitted to ONE long-lived pool
    // (seeded once), rather than a scoped pool per call — and reusable
    // across many batches on the same pool without reseeding
    let mut rng = Pcg::seed(55);
    let net = mnist_mlp(14, 18, &[12, 7], 4);
    let xq = Matrix::from_vec(20, 18, rng.normal_vec(20 * 18));
    let (packed, unpacked) = packed_twins(&net, &xq);
    let packed = Arc::new(packed);
    let x = Matrix::from_vec(11, 18, rng.normal_vec(11 * 18));
    let want = unpacked.forward(&x);
    for workers in [1usize, 2, 4] {
        let pool = WorkerPool::new(workers);
        // several batches on one pool: the shard count may exceed, match,
        // or ragged-divide the row count
        for shards in [1usize, 2, 4, 5] {
            let got = forward_sharded_on(&pool, &packed, &x, shards);
            assert_bits(&got, &want, &format!("pool workers={workers} shards={shards}"));
        }
        pool.shutdown();
    }
}

#[test]
fn saved_model_serves_packed_and_bit_identical() {
    // the deployment path: quantize → save → load keeps layers index-
    // resident, and the loaded net's forward matches the pre-save
    // float-quantized network bit for bit
    let mut rng = Pcg::seed(53);
    let net = mnist_mlp(13, 16, &[10], 3);
    let xq = Matrix::from_vec(20, 16, rng.normal_vec(20 * 16));
    let out =
        quantize_network(&net, &xq, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let hints = hints_from_outcome(&out);
    let path =
        std::env::temp_dir().join(format!("gpfq_test_kernels_{}.gpfq", std::process::id()));
    save_file(&out.network, &hints, &path).expect("save");
    let loaded = load_file(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    assert!(packed_layer_count(&loaded) > 0, "load must keep packed layers resident");
    let x = Matrix::from_vec(7, 16, rng.normal_vec(7 * 16));
    assert_bits(&loaded.forward(&x), &out.network.forward(&x), "save/load packed forward");
}

/// Epilogue-seam regression, fusing side: a BatchNorm whose channel
/// count divides the conv's `cout` folds into the pre-fold GEMM epilogue
/// — the fold is a pure permutation and `(p·cout + c) % channels ==
/// c % channels` whenever `channels | cout`, so fused must equal the
/// unfused oracle bit for bit even with per-channel stats that differ.
#[test]
fn conv_bn_fusion_exact_when_channels_divide_cout() {
    let mut rng = Pcg::seed(56);
    let img = ImgShape { h: 5, w: 5, c: 2 };
    let mut b = NetworkBuilder::new(Shape::Img(img), 7);
    b.conv(3, 3, 4, 1, Activation::Relu).flatten().dense(3, Activation::None);
    // hand-insert a 2-channel BN right after the conv (the builder always
    // matches channels to cout; the divisor case needs constructing), then
    // reassemble with per-layer shapes kept consistent: conv 5x5 → 3x3x4
    // flattened to 36, BN preserves it, dense → 3
    let mut bn = BatchNorm::new(2);
    bn.gamma = rng.uniform_vec(2, 0.5, 1.5);
    bn.beta = rng.uniform_vec(2, -0.5, 0.5);
    bn.running_mean = rng.uniform_vec(2, -0.3, 0.3);
    bn.running_var = rng.uniform_vec(2, 0.5, 2.0);
    let mut layers = b.build().layers;
    layers.insert(1, Layer::BatchNorm(bn));
    let shapes = vec![Shape::Flat(36), Shape::Flat(36), Shape::Flat(3)];
    let net = Network::from_parts(Shape::Img(img), layers, shapes);
    let x = Matrix::from_vec(4, img.len(), rng.normal_vec(4 * img.len()));
    assert_bits(&net.forward(&x), &net.forward_unfused(&x), "conv+BN fused (channels | cout)");
}

/// Epilogue-seam regression, guarding side: a BatchNorm over the conv's
/// *folded* width (channels = oh·ow·cout, via flatten→batchnorm) does NOT
/// divide `cout`, so pre-fold fusion would read the wrong per-channel
/// stats — `fusable_bn` must refuse it and fall back to the separate BN
/// layer, keeping fused ≡ unfused.
#[test]
fn conv_bn_fusion_guard_refuses_nondivisible_channels() {
    let mut rng = Pcg::seed(57);
    let img = ImgShape { h: 5, w: 5, c: 1 };
    let mut b = NetworkBuilder::new(Shape::Img(img), 8);
    b.conv(3, 3, 2, 1, Activation::Relu).flatten().batchnorm().dense(3, Activation::None);
    let mut net = b.build();
    // distinct per-channel stats give the guard teeth: a wrong channel
    // index would visibly change the bits
    if let Layer::BatchNorm(bn) = &mut net.layers[1] {
        let ch = bn.channels;
        // cout = 2 is not divisible by the folded channel count, so the
        // fusability predicate (cout % channels == 0) must reject this
        assert_ne!(2 % ch, 0, "test premise: channels {ch} must not divide cout 2");
        bn.gamma = rng.uniform_vec(ch, 0.5, 1.5);
        bn.beta = rng.uniform_vec(ch, -0.5, 0.5);
        bn.running_mean = rng.uniform_vec(ch, -0.3, 0.3);
        bn.running_var = rng.uniform_vec(ch, 0.5, 2.0);
    } else {
        panic!("layer 1 should be the flattened BatchNorm");
    }
    let x = Matrix::from_vec(3, img.len(), rng.normal_vec(3 * img.len()));
    assert_bits(&net.forward(&x), &net.forward_unfused(&x), "conv+BN unfusable fallback");
}

#[test]
fn pack_unpack_roundtrip_preserves_weights_exactly() {
    // Alphabet::nearest and Alphabet::level share one formula
    // (-alpha + step*j), so decode reproduces the quantizer's f32 output
    // exactly — not approximately
    let mut rng = Pcg::seed(54);
    let net = mnist_mlp(15, 12, &[8], 3);
    let xq = Matrix::from_vec(16, 12, rng.normal_vec(16 * 12));
    let out =
        quantize_network(&net, &xq, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let packed = pack_network(&out.network, &hints_from_outcome(&out));
    let unpacked = unpack_network(&packed);
    for (i, (a, b)) in out.network.layers.iter().zip(&unpacked.layers).enumerate() {
        if let (Some(wa), Some(wb)) = (a.weights(), b.weights()) {
            assert_eq!(wa.data, wb.data, "layer {i}: decode changed weights");
        }
    }
}
