//! Exactness pins for the packed-domain inference kernels (`nn::kernels`).
//!
//! The PR 6 contract: a packed network's forward pass is **bit-identical**
//! to the same network eagerly decoded back to f32 — on an MLP and on a
//! conv/pool/batchnorm CNN, for any batch sharding (worker counts 1/2/4
//! via `forward_sharded`), and straight off the `.gpfq` save→load path.
//! The per-GEMM argument (packed/tiled vs the frozen naive summation
//! tree) is property-tested in `test_properties.rs`; this file pins the
//! whole-network composition.

use gpfq::coordinator::pipeline::{quantize_network, PipelineConfig};
use gpfq::data::rng::Pcg;
use gpfq::nn::conv::ImgShape;
use gpfq::nn::kernels::{forward_sharded, pack_network, packed_layer_count, unpack_network};
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, Network};
use gpfq::nn::serialize::{hints_from_outcome, load_file, save_file};

fn assert_bits(a: &Matrix, b: &Matrix, tag: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{tag}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: element {i}: {x} vs {y}");
    }
}

/// Quantize `net` and return its (packed-resident, eagerly-decoded) twins.
fn packed_twins(net: &Network, x_quant: &Matrix) -> (Network, Network) {
    let out =
        quantize_network(net, x_quant, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let packed = pack_network(&out.network, &hints_from_outcome(&out));
    assert!(packed_layer_count(&packed) > 0, "quantized net should pack");
    let unpacked = unpack_network(&packed);
    assert_eq!(packed_layer_count(&unpacked), 0, "unpack must clear every packed layer");
    (packed, unpacked)
}

#[test]
fn mlp_packed_forward_bit_identical_across_worker_counts() {
    let mut rng = Pcg::seed(51);
    let net = mnist_mlp(11, 20, &[14, 9], 4);
    let xq = Matrix::from_vec(24, 20, rng.normal_vec(24 * 20));
    let (packed, unpacked) = packed_twins(&net, &xq);
    assert!(packed.summary().contains("pdense"), "{}", packed.summary());
    let x = Matrix::from_vec(13, 20, rng.normal_vec(13 * 20));
    let want = unpacked.forward(&x);
    for workers in [1usize, 2, 4] {
        let got = forward_sharded(&packed, &x, workers);
        assert_bits(&got, &want, &format!("mlp workers={workers}"));
    }
}

#[test]
fn cnn_packed_forward_bit_identical_across_worker_counts() {
    // conv + maxpool + batchnorm + dense all on the forward path; only the
    // conv/dense layers pack, the rest must compose around them unchanged
    let mut rng = Pcg::seed(52);
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = cifar_cnn(12, img, &[3], 10, 3);
    let xq = Matrix::from_vec(10, img.len(), rng.normal_vec(10 * img.len()));
    let (packed, unpacked) = packed_twins(&net, &xq);
    assert!(packed.summary().contains("pconv"), "{}", packed.summary());
    let x = Matrix::from_vec(9, img.len(), rng.normal_vec(9 * img.len()));
    let want = unpacked.forward(&x);
    for workers in [1usize, 2, 4] {
        let got = forward_sharded(&packed, &x, workers);
        assert_bits(&got, &want, &format!("cnn workers={workers}"));
    }
}

#[test]
fn saved_model_serves_packed_and_bit_identical() {
    // the deployment path: quantize → save → load keeps layers index-
    // resident, and the loaded net's forward matches the pre-save
    // float-quantized network bit for bit
    let mut rng = Pcg::seed(53);
    let net = mnist_mlp(13, 16, &[10], 3);
    let xq = Matrix::from_vec(20, 16, rng.normal_vec(20 * 16));
    let out =
        quantize_network(&net, &xq, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let hints = hints_from_outcome(&out);
    let path =
        std::env::temp_dir().join(format!("gpfq_test_kernels_{}.gpfq", std::process::id()));
    save_file(&out.network, &hints, &path).expect("save");
    let loaded = load_file(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    assert!(packed_layer_count(&loaded) > 0, "load must keep packed layers resident");
    let x = Matrix::from_vec(7, 16, rng.normal_vec(7 * 16));
    assert_bits(&loaded.forward(&x), &out.network.forward(&x), "save/load packed forward");
}

#[test]
fn pack_unpack_roundtrip_preserves_weights_exactly() {
    // Alphabet::nearest and Alphabet::level share one formula
    // (-alpha + step*j), so decode reproduces the quantizer's f32 output
    // exactly — not approximately
    let mut rng = Pcg::seed(54);
    let net = mnist_mlp(15, 12, &[8], 3);
    let xq = Matrix::from_vec(16, 12, rng.normal_vec(16 * 12));
    let out =
        quantize_network(&net, &xq, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let packed = pack_network(&out.network, &hints_from_outcome(&out));
    let unpacked = unpack_network(&packed);
    for (i, (a, b)) in out.network.layers.iter().zip(&unpacked.layers).enumerate() {
        if let (Some(wa), Some(wb)) = (a.weights(), b.weights()) {
            assert_eq!(wa.data, wb.data, "layer {i}: decode changed weights");
        }
    }
}
