//! Runtime parity: every artifact kind must agree with its native Rust
//! twin.  These tests skip (with a notice) when `make artifacts` has not
//! run; CI runs them after building artifacts.

use std::sync::Arc;

use gpfq::data::rng::Pcg;
use gpfq::nn::matrix::Matrix;
use gpfq::quant::alphabet::Alphabet;
use gpfq::quant::gpfq::{gpfq_layer, LayerData};
use gpfq::quant::msq::msq_matrix;
use gpfq::runtime::{Arg, Runtime};

fn runtime() -> Option<Arc<Runtime>> {
    let rt = Runtime::try_default();
    if rt.is_none() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    }
    rt.map(Arc::new)
}

#[test]
fn msq_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let Some(info) = rt.manifest().artifacts.iter().find(|a| a.kind == "msq").cloned() else {
        return;
    };
    let (n, b) = (info.params[0].shape[0], info.params[0].shape[1]);
    let m_levels = info.meta_usize("M").unwrap();
    let mut rng = Pcg::seed(1);
    let w = Matrix::from_vec(n, b, rng.uniform_vec(n * b, -2.0, 2.0));
    for alpha in [0.5f32, 1.0, 2.3] {
        let got = rt.execute_info(&info, &[Arg::Mat(&w), Arg::Scalar(alpha)]).unwrap();
        let want = msq_matrix(&w, Alphabet::new(alpha, m_levels));
        let maxdiff = got[0]
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(maxdiff < 1e-5, "alpha {alpha}: max diff {maxdiff}");
    }
}

#[test]
fn dense_artifact_matches_native_forward() {
    let Some(rt) = runtime() else { return };
    let Some(info) = rt
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == "dense" && a.name.ends_with("relu"))
        .cloned()
    else {
        return;
    };
    let (m, n) = (info.params[0].shape[0], info.params[0].shape[1]);
    let k = info.params[1].shape[1];
    let mut rng = Pcg::seed(2);
    let y = Matrix::from_vec(m, n, rng.normal_vec(m * n));
    let w = Matrix::from_vec(n, k, rng.normal_vec(n * k));
    let b: Vec<f32> = rng.normal_vec(k);
    let got = rt.execute_info(&info, &[Arg::Mat(&y), Arg::Mat(&w), Arg::Vec(&b)]).unwrap();
    // native: relu(Y @ W + b)
    let mut want = y.matmul(&w);
    want.add_row_vec(&b);
    for v in &mut want.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let maxdiff = got[0]
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 1e-2, "max diff {maxdiff}"); // f32 matmul accumulation order differs
}

#[test]
fn gpfq_artifact_matches_native_all_levels() {
    let Some(rt) = runtime() else { return };
    let man = rt.manifest();
    let mut tested = 0;
    let infos: Vec<_> = man.artifacts.iter().filter(|a| a.kind == "gpfq").cloned().collect();
    for info in infos {
        let m = info.meta_usize("m").unwrap();
        let n = info.meta_usize("n").unwrap();
        let b = info.meta_usize("b").unwrap();
        let levels = info.meta_usize("M").unwrap();
        if n > 500 && tested > 0 {
            continue; // keep the suite fast: one big + all small shapes
        }
        let mut rng = Pcg::seed(3 + n as u64);
        let y = Matrix::from_vec(m, n, rng.normal_vec(m * n));
        let mut yq = y.clone();
        for v in yq.data.iter_mut() {
            *v += 0.05 * rng.normal() as f32;
        }
        let w = Matrix::from_vec(n, b, rng.uniform_vec(n * b, -1.0, 1.0));
        let alpha = 0.9f32;
        let got = rt
            .execute_info(&info, &[Arg::Mat(&y), Arg::Mat(&yq), Arg::Mat(&w), Arg::Scalar(alpha)])
            .unwrap();
        let native = gpfq_layer(&LayerData::new(&y, &yq), &w, Alphabet::new(alpha, levels));
        let maxdiff = got[0]
            .data
            .iter()
            .zip(&native.q.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(maxdiff < 1e-5, "{}: max diff {maxdiff}", info.name);
        tested += 1;
    }
    assert!(tested >= 2, "expected at least two gpfq artifacts, tested {tested}");
}

#[test]
fn train_step_artifact_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let Some(info) = rt.manifest().artifacts.iter().find(|a| a.kind == "train_step").cloned() else {
        return;
    };
    // dims from the manifest: params are (W1,b1,...,x,y,lr)
    let n_params = info.params.len() - 3;
    let mut rng = Pcg::seed(4);
    let mut params: Vec<Matrix> = Vec::new();
    for p in &info.params[..n_params] {
        let (r, c) = if p.shape.len() == 2 { (p.shape[0], p.shape[1]) } else { (1, p.shape[0]) };
        let scale = (2.0 / r as f64).sqrt() as f32;
        params.push(Matrix::from_vec(r, c, rng.normal_vec(r * c).iter().map(|v| v * scale).collect()));
    }
    let batch = info.params[n_params].shape[0];
    let in_dim = info.params[n_params].shape[1];
    let classes = info.params[n_params + 1].shape[1];
    let x = Matrix::from_vec(batch, in_dim, rng.normal_vec(batch * in_dim));
    let mut y = Matrix::zeros(batch, classes);
    for r in 0..batch {
        *y.at_mut(r, r % classes) = 1.0;
    }
    let mut losses = Vec::new();
    for _ in 0..30 {
        let mut args: Vec<Arg> = params.iter().map(Arg::Mat).collect();
        args.push(Arg::Mat(&x));
        args.push(Arg::Mat(&y));
        args.push(Arg::Scalar(0.1));
        let out = rt.execute_info(&info, &args).unwrap();
        losses.push(out.last().unwrap().at(0, 0) as f64);
        params = out[..out.len() - 1].to_vec();
    }
    assert!(
        losses.last().unwrap() < &(0.5 * losses[0]),
        "train_step failed to learn: {:.4} -> {:.4}",
        losses[0],
        losses.last().unwrap()
    );
}

#[test]
fn mlp_fwd_artifact_matches_manual_composition() {
    let Some(rt) = runtime() else { return };
    let Some(info) = rt.manifest().artifacts.iter().find(|a| a.kind == "mlp_fwd").cloned() else {
        return;
    };
    let batch = info.params[0].shape[0];
    let mut rng = Pcg::seed(5);
    let x = Matrix::from_vec(batch, info.params[0].shape[1], rng.normal_vec(batch * info.params[0].shape[1]));
    let mut params: Vec<Matrix> = Vec::new();
    for p in &info.params[1..] {
        let (r, c) = if p.shape.len() == 2 { (p.shape[0], p.shape[1]) } else { (1, p.shape[0]) };
        params.push(Matrix::from_vec(r, c, rng.normal_vec(r * c)));
    }
    let mut args: Vec<Arg> = vec![Arg::Mat(&x)];
    args.extend(params.iter().map(Arg::Mat));
    let got = &rt.execute_info(&info, &args).unwrap()[0];
    // manual: relu(...relu(xW1+b1)...)WL+bL
    let mut h = x.clone();
    let layers = params.len() / 2;
    for i in 0..layers {
        let mut z = h.matmul(&params[2 * i]);
        z.add_row_vec(params[2 * i + 1].row(0));
        if i + 1 < layers {
            for v in &mut z.data {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        h = z;
    }
    let maxdiff = got
        .data
        .iter()
        .zip(&h.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 1e-2, "max diff {maxdiff}");
}
