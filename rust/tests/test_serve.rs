//! End-to-end guarantees of the serving subsystem, pinned hard:
//!
//! 1. **Loopback parity** — logits served through the full
//!    save → load → HTTP → micro-batch → worker-pool path are
//!    **bit-identical** to a direct in-process `Network::forward` on the
//!    loaded model, for batched (concurrent clients) and single-request
//!    traffic, across server worker counts, on an MNIST-shaped MLP and a
//!    CIFAR-shaped CNN (conv + maxpool + batchnorm on the request path).
//! 2. **Protocol behavior** — /healthz and /stats answer; malformed JSON,
//!    wrong input width, unknown routes and wrong methods produce the
//!    right HTTP errors and never take the server down.
//! 3. **Lifecycle** — graceful shutdown completes with requests in flight
//!    and the server loop returns cleanly.
//!
//! The micro-batcher's scheduling policy itself is unit-tested with
//! synthetic clocks in `serve::batch`; these tests are the sockets-and-all
//! integration layer above it.

use std::net::SocketAddr;
use std::sync::Arc;

use gpfq::coordinator::pipeline::{quantize_network, PipelineConfig};
use gpfq::coordinator::scheduler::pool_seedings;
use gpfq::data::rng::Pcg;
use gpfq::nn::conv::ImgShape;
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, Network};
use gpfq::nn::serialize::{hints_from_outcome, load_file, save_file};
use gpfq::serve::{
    bench_serve, http_json_request, BatchPolicy, BenchServeConfig, HttpClient, ServeConfig,
    Server, ServerHandle,
};
use gpfq::util::json::Json;

/// Quantize `net`, round-trip it through the packed on-disk format, and
/// hand back the **loaded** network — the bytes a deployment would serve.
fn packed_round_trip(net: &Network, x_quant: &Matrix, tag: &str) -> Network {
    let out =
        quantize_network(net, x_quant, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let hints = hints_from_outcome(&out);
    let path = std::env::temp_dir()
        .join(format!("gpfq_test_serve_{}_{}.gpfq", tag, std::process::id()));
    save_file(&out.network, &hints, &path).expect("save");
    let loaded = load_file(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    loaded
}

fn start_server(
    net: Network,
    workers: usize,
    max_batch: usize,
    max_wait_us: u64,
) -> (ServerHandle, SocketAddr, std::thread::JoinHandle<gpfq::error::Result<()>>) {
    start_server_sharded(net, workers, max_batch, max_wait_us, ServeConfig::default().shard_threshold)
}

fn start_server_sharded(
    net: Network,
    workers: usize,
    max_batch: usize,
    max_wait_us: u64,
    shard_threshold: usize,
) -> (ServerHandle, SocketAddr, std::thread::JoinHandle<gpfq::error::Result<()>>) {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        batch: BatchPolicy::new(max_batch, max_wait_us),
        shard_threshold,
        ..Default::default()
    };
    let server = Server::bind(net, &cfg).expect("bind");
    let handle = server.handle();
    let addr = server.local_addr();
    let join = std::thread::spawn(move || server.run());
    (handle, addr, join)
}

fn infer_one(addr: SocketAddr, row: &[f32]) -> Vec<f32> {
    let body = Json::obj([("input", Json::from_f32s(row))]);
    let (status, resp) = http_json_request(addr, "POST", "/infer", Some(&body)).expect("request");
    assert_eq!(status, 200, "{resp}");
    resp.get("logits").as_f32_vec().expect("logits array")
}

fn assert_bits_equal(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: width");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: logit {i} {x} vs {y}");
    }
}

/// Acceptance pin: MLP logits through the full HTTP + micro-batch path are
/// bit-identical to in-process forward, for concurrent (batched) and
/// sequential (single-request) traffic, across worker counts.
#[test]
fn mlp_loopback_parity_batched_and_single_across_worker_counts() {
    let mut rng = Pcg::seed(41);
    let float_net = mnist_mlp(11, 24, &[16, 8], 4);
    let x_quant = Matrix::from_vec(32, 24, rng.normal_vec(32 * 24));
    let net = packed_round_trip(&float_net, &x_quant, "mlp");
    let x = Arc::new(Matrix::from_vec(24, 24, rng.normal_vec(24 * 24)));
    let reference = Arc::new(net.forward(&x));

    for workers in [1usize, 2, 4] {
        // max_batch 4 with 6 concurrent clients: real coalescing happens
        let (handle, addr, join) = start_server(net.clone(), workers, 4, 1500);
        std::thread::scope(|s| {
            for c in 0..6usize {
                let x = x.clone();
                let reference = reference.clone();
                s.spawn(move || {
                    for i in (c..24).step_by(6) {
                        let served = infer_one(addr, x.row(i));
                        assert_bits_equal(
                            &served,
                            reference.row(i),
                            &format!("workers={workers} concurrent row {i}"),
                        );
                    }
                });
            }
        });
        // single-request traffic: one client, no co-travellers — the
        // max_wait flush path must serve identical bits
        for i in [0usize, 7, 23] {
            let served = infer_one(addr, x.row(i));
            let tag = format!("workers={workers} solo row {i}");
            assert_bits_equal(&served, reference.row(i), &tag);
        }
        handle.shutdown();
        join.join().unwrap().expect("server loop");
    }
}

/// Same pin on a CIFAR-shaped CNN: conv, maxpool and batchnorm layers all
/// sit on the request path, driven through the bench-serve loopback
/// generator (which also produces the latency/batch report).
#[test]
fn cnn_loopback_parity_via_bench_serve() {
    let mut rng = Pcg::seed(43);
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let float_net = cifar_cnn(13, img, &[3], 12, 3);
    let x_quant = Matrix::from_vec(10, img.len(), rng.normal_vec(10 * img.len()));
    let net = packed_round_trip(&float_net, &x_quant, "cnn");
    let replay = Matrix::from_vec(12, img.len(), rng.normal_vec(12 * img.len()));
    let cfg = BenchServeConfig {
        requests: 48,
        clients: 6,
        serve: ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            batch: BatchPolicy::new(4, 1500),
            ..Default::default()
        },
    };
    let report = bench_serve(net, &replay, &cfg).expect("bench");
    assert!(report.parity_ok, "{} served rows diverged from forward()", report.mismatches);
    assert_eq!(report.mismatches, 0);
    assert_eq!(report.server.requests, 48, "every request served");
    assert_eq!(report.server.errors, 0);
    assert!(report.client_qps > 0.0);
    assert!(report.lat_p99_us >= report.lat_p50_us);
    // the batch histogram must account for exactly the served requests
    let batched: u64 = report.server.batch_hist.iter().map(|(&s, &n)| s as u64 * n).sum();
    assert_eq!(batched, 48);
    assert!(
        report.server.batch_hist.keys().all(|&s| (1..=4).contains(&s)),
        "batch sizes within policy: {:?}",
        report.server.batch_hist
    );
    // the packed kernel actually served this model, bit-identically
    assert!(report.packed_layers > 0, "round-tripped net should keep packed layers resident");
    assert!(report.kernel_parity_ok, "packed kernel diverged from unpacked baseline");
    assert!(report.packed_forward_seconds > 0.0 && report.unpacked_forward_seconds > 0.0);
    // the report serializes to valid JSON with the acceptance fields
    let doc = gpfq::util::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(doc.get("parity_ok").as_bool(), Some(true));
    assert!(doc.get("client_latency_p50_us").as_f64().is_some());
    assert!(doc.get("server").get("batch_hist").as_obj().is_some());
    assert!(doc.get("client_qps").as_f64().unwrap() > 0.0);
    assert_eq!(doc.get("kernel_parity_ok").as_bool(), Some(true));
    assert!(doc.get("packed_speedup").as_f64().is_some());
}

/// Multi-row requests (`{"inputs": [...]}`) batch each row independently
/// and still return bit-identical logits in request order.
#[test]
fn multi_row_requests_preserve_order_and_bits() {
    let mut rng = Pcg::seed(47);
    let net = mnist_mlp(17, 12, &[8], 3);
    let x = Matrix::from_vec(5, 12, rng.normal_vec(60));
    let reference = net.forward(&x);
    let (handle, addr, join) = start_server(net, 2, 3, 1000);
    let rows: Vec<Json> = (0..5).map(|r| Json::from_f32s(x.row(r))).collect();
    let body = Json::obj([("inputs", Json::Arr(rows))]);
    let (status, resp) = http_json_request(addr, "POST", "/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let outputs = resp.get("outputs").as_arr().expect("outputs array");
    assert_eq!(outputs.len(), 5);
    for (r, out) in outputs.iter().enumerate() {
        let served = out.get("logits").as_f32_vec().unwrap();
        assert_bits_equal(&served, reference.row(r), &format!("inputs[{r}]"));
        let argmax = out.get("argmax").as_usize().unwrap();
        let want = reference
            .row(r)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(argmax, want, "row {r} argmax");
    }
    handle.shutdown();
    join.join().unwrap().expect("server loop");
}

#[test]
fn protocol_endpoints_and_error_paths() {
    let net = mnist_mlp(19, 10, &[6], 2);
    let (handle, addr, join) = start_server(net, 1, 8, 500);

    // healthz reports the model
    let (status, health) = http_json_request(addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("status").as_str(), Some("ok"));
    assert_eq!(health.get("input_width").as_usize(), Some(10));
    assert!(health.get("model").as_str().unwrap().contains("dense"));

    // a good request, so /stats has something to report
    let row = vec![0.25f32; 10];
    let body = Json::obj([("input", Json::from_f32s(&row))]);
    let (status, resp) = http_json_request(addr, "POST", "/infer", Some(&body)).unwrap();
    assert_eq!(status, 200);
    assert_eq!(resp.get("logits").as_f32_vec().unwrap().len(), 2);

    // error paths: each must answer the right status and leave the server up
    let bad_width = Json::obj([("input", Json::from_f32s(&[1.0, 2.0]))]);
    let (status, resp) = http_json_request(addr, "POST", "/infer", Some(&bad_width)).unwrap();
    assert_eq!(status, 400);
    assert!(resp.get("error").as_str().unwrap().contains("width"));

    let no_input = Json::obj([("wrong", Json::Bool(true))]);
    let (status, _) = http_json_request(addr, "POST", "/infer", Some(&no_input)).unwrap();
    assert_eq!(status, 400);

    let text_rows = Json::obj([("input", Json::Arr(vec![Json::Str("x".into())]))]);
    let (status, _) = http_json_request(addr, "POST", "/infer", Some(&text_rows)).unwrap();
    assert_eq!(status, 400);

    let (status, _) = http_json_request(addr, "GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_json_request(addr, "GET", "/infer", None).unwrap();
    assert_eq!(status, 405);

    // stats counted the one success and the failures
    let (status, stats) = http_json_request(addr, "GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(stats.get("requests").as_usize(), Some(1));
    assert!(stats.get("errors").as_usize().unwrap() >= 4);
    assert!(stats.get("batch_hist").get("1").as_usize().unwrap() >= 1);
    assert!(stats.get("latency_p50_us").as_f64().unwrap() > 0.0);

    // the server survives all of the above and still shuts down cleanly
    let (status, _) = http_json_request(addr, "POST", "/infer", Some(&body)).unwrap();
    assert_eq!(status, 200);
    handle.shutdown();
    join.join().unwrap().expect("server loop");
}

/// Every batch routed through the row-sharded multi-core path
/// (`shard_threshold` 1 forces it even for singleton batches) serves
/// logits bit-identical to the serial forward — on a packed model, with
/// pool workers actually running the shards.
#[test]
fn sharded_batch_path_serves_bit_identical_logits() {
    let mut rng = Pcg::seed(59);
    let float_net = mnist_mlp(29, 16, &[10, 6], 3);
    let x_quant = Matrix::from_vec(24, 16, rng.normal_vec(24 * 16));
    let net = packed_round_trip(&float_net, &x_quant, "sharded");
    let x = Matrix::from_vec(13, 16, rng.normal_vec(13 * 16));
    let reference = net.forward(&x);
    let seedings_before = pool_seedings();
    let (handle, addr, join) = start_server_sharded(net, 4, 16, 2000, 1);
    // a multi-row request lands as one 13-row batch ≥ threshold 1 → the
    // executor runs it through forward_sharded_on across 4 pool workers
    let rows: Vec<Json> = (0..13).map(|r| Json::from_f32s(x.row(r))).collect();
    let body = Json::obj([("inputs", Json::Arr(rows))]);
    let (status, resp) = http_json_request(addr, "POST", "/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let outputs = resp.get("outputs").as_arr().expect("outputs array");
    assert_eq!(outputs.len(), 13);
    for (r, out) in outputs.iter().enumerate() {
        let served = out.get("logits").as_f32_vec().unwrap();
        assert_bits_equal(&served, reference.row(r), &format!("sharded inputs[{r}]"));
    }
    // singleton batches take the same path at threshold 1
    for i in [0usize, 5, 12] {
        let served = infer_one(addr, x.row(i));
        assert_bits_equal(&served, reference.row(i), &format!("sharded solo row {i}"));
    }
    handle.shutdown();
    join.join().unwrap().expect("server loop");
    // the server seeded its pool (lower bound only: tests in this binary
    // run in parallel and seed pools of their own; the strict ==1 gate is
    // bench-serve's, which runs alone in its process)
    assert!(pool_seedings() >= seedings_before + 1, "server never seeded a pool");
}

/// Keep-alive: many requests on ONE connection return the same bits as
/// one-shot connections, mixing infer and control endpoints; a client
/// that asks `Connection: close` still gets closed.
#[test]
fn keep_alive_connection_serves_many_requests_bit_identically() {
    let mut rng = Pcg::seed(61);
    let net = mnist_mlp(31, 14, &[8], 3);
    let x = Matrix::from_vec(6, 14, rng.normal_vec(6 * 14));
    let reference = net.forward(&x);
    let (handle, addr, join) = start_server(net, 2, 4, 1000);
    let mut client = HttpClient::connect(addr).expect("connect");
    for round in 0..3 {
        for i in 0..6usize {
            let body = Json::obj([("input", Json::from_f32s(x.row(i)))]);
            let (status, resp) = client.request("POST", "/infer", Some(&body)).expect("request");
            assert_eq!(status, 200, "{resp}");
            let served = resp.get("logits").as_f32_vec().expect("logits");
            assert_bits_equal(&served, reference.row(i), &format!("keep-alive r{round} row {i}"));
        }
        // control endpoints ride the same connection
        let (status, health) = client.request("GET", "/healthz", None).expect("healthz");
        assert_eq!(status, 200);
        assert_eq!(health.get("status").as_str(), Some("ok"));
    }
    // errors answer on the connection without tearing it down
    let bad = Json::obj([("input", Json::from_f32s(&[1.0]))]);
    let (status, _) = client.request("POST", "/infer", Some(&bad)).expect("bad width");
    assert_eq!(status, 400);
    let (status, _) = client.request("GET", "/healthz", None).expect("still alive");
    assert_eq!(status, 200);
    // the connection-per-request path (Connection: close) coexists
    let served = infer_one(addr, x.row(0));
    assert_bits_equal(&served, reference.row(0), "close-mode after keep-alive");
    handle.shutdown();
    join.join().unwrap().expect("server loop");
}

#[test]
fn graceful_shutdown_with_traffic_in_flight() {
    let mut rng = Pcg::seed(53);
    let net = mnist_mlp(23, 8, &[6], 2);
    let x = Matrix::from_vec(4, 8, rng.normal_vec(32));
    let reference = net.forward(&x);
    // large max_wait: in-flight requests sit in the batcher when shutdown
    // lands, and the drain must still answer them
    let (handle, addr, join) = start_server(net, 2, 64, 50_000);
    std::thread::scope(|s| {
        for c in 0..4usize {
            let reference = &reference;
            let x = &x;
            s.spawn(move || {
                let served = infer_one(addr, x.row(c));
                assert_bits_equal(&served, reference.row(c), &format!("in-flight row {c}"));
            });
        }
        // give the clients a moment to be queued, then pull the plug while
        // their requests are still sitting in the batcher: the graceful
        // drain must answer every accepted request before the loop exits
        std::thread::sleep(std::time::Duration::from_millis(30));
        handle.shutdown();
    });
    join.join().unwrap().expect("server loop returns Ok after drain");
    // the listener is gone afterwards
    assert!(http_json_request(addr, "GET", "/healthz", None).is_err());
}
