//! Property-based tests over the quantization algorithms and coordinator
//! invariants (mini-proptest framework: `gpfq::testing::prop`).

use gpfq::coordinator::pipeline::{quantize_network, Method, PipelineConfig};
use gpfq::coordinator::scheduler::{run_jobs, SchedulerConfig};
use gpfq::nn::conv::ImgShape;
use gpfq::nn::kernels::{axpy_lanes, forward_sharded, pack_network, packed_matmul, PackedWeights, LANES};
use gpfq::nn::matrix::{axpy, norm_sq, Matrix};
use gpfq::nn::network::{cifar_cnn, mnist_mlp, NetworkBuilder, Shape};
use gpfq::nn::serialize::hints_from_outcome;
use gpfq::nn::Activation;
use gpfq::quant::alphabet::Alphabet;
use gpfq::quant::exhaustive::exhaustive_neuron;
use gpfq::quant::gpfq::{gpfq_layer, gpfq_neuron, LayerData};
use gpfq::quant::msq::msq_vec;
use gpfq::quant::sigma_delta::sigma_delta;
use gpfq::testing::prop::{forall, prop_assert, Gen};

fn rand_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, g.normal_vec(rows * cols))
}

// ---------------------------------------------------------------------------
// alphabet invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_nearest_is_true_argmin() {
    forall("alphabet nearest == argmin over levels", 200, |g| {
        let m = *g.choice(&[2usize, 3, 4, 5, 8, 16, 31]);
        let alpha = g.f32_in(0.05, 4.0);
        let a = Alphabet::new(alpha, m);
        let z = g.f32_in(-3.0 * alpha, 3.0 * alpha);
        let q = a.nearest(z);
        let best = a
            .levels()
            .into_iter()
            .map(|l| (l - z).abs())
            .fold(f32::MAX, f32::min);
        prop_assert(
            ((q - z).abs() - best).abs() <= 1e-4 * alpha,
            format!("z={z} q={q} best_dist={best} (alpha={alpha}, M={m})"),
        )
    });
}

#[test]
fn prop_quantizer_idempotent_and_bounded() {
    forall("Q(Q(z)) == Q(z) and |Q(z)| <= alpha", 200, |g| {
        let m = *g.choice(&[2usize, 3, 8]);
        let alpha = g.f32_in(0.1, 3.0);
        let a = Alphabet::new(alpha, m);
        let z = g.f32_in(-10.0, 10.0);
        let q = a.nearest(z);
        prop_assert(
            (a.nearest(q) - q).abs() < 1e-6 && q.abs() <= alpha + 1e-6,
            format!("z={z} q={q}"),
        )
    });
}

// ---------------------------------------------------------------------------
// GPFQ invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_gpfq_state_identity() {
    // ‖u_N‖ == ‖Yw − Ỹq‖ exactly (Section 4 identity), for random shapes
    forall("state identity", 30, |g| {
        let m = g.dim(24);
        let n = g.dim(40).max(2);
        let y = rand_matrix(g, m, n);
        let yq = rand_matrix(g, m, n);
        let w: Vec<f32> = g.uniform_vec(n, -1.0, 1.0);
        let a = Alphabet::ternary(g.f32_in(0.3, 2.0));
        let data = LayerData::new(&y, &yq);
        let mut u = vec![0.0f32; m];
        let res = gpfq_neuron(&data, &w, a, &mut u);
        // recompute ‖Yw − Ỹq‖ from scratch
        let mut yw = vec![0.0f32; m];
        let mut yqq = vec![0.0f32; m];
        for t in 0..n {
            axpy(w[t], &y.col(t), &mut yw);
            axpy(res.q[t], &yq.col(t), &mut yqq);
        }
        let diff: Vec<f32> = yw.iter().zip(&yqq).map(|(a, b)| a - b).collect();
        let direct = norm_sq(&diff).sqrt() as f64;
        prop_assert(
            (direct - res.err).abs() < 1e-3 * (1.0 + direct),
            format!("direct {direct} vs state {}", res.err),
        )
    });
}

#[test]
fn prop_gpfq_never_worse_than_msq_first_layer() {
    // greedy step-t choice minimizes the step-t objective; empirically the
    // full-path error beats MSQ on generic Gaussian data (median property —
    // assert over the batch, not per case).
    let mut gpfq_wins = 0usize;
    let mut total = 0usize;
    forall("gpfq vs msq accumulation", 40, |g| {
        let m = g.dim(16);
        let n = (4 * g.dim(32)).max(8);
        let y = rand_matrix(g, m, n);
        let w: Vec<f32> = g.uniform_vec(n, -1.0, 1.0);
        let a = Alphabet::ternary(1.0);
        let data = LayerData::first_layer(&y);
        let mut u = vec![0.0f32; m];
        let res = gpfq_neuron(&data, &w, a, &mut u);
        let qm = msq_vec(&w, a);
        let mut diff = vec![0.0f32; m];
        for t in 0..n {
            axpy(w[t] - qm[t], &y.col(t), &mut diff);
        }
        let msq_err = norm_sq(&diff).sqrt() as f64;
        total += 1;
        if res.err <= msq_err + 1e-6 {
            gpfq_wins += 1;
        }
        Ok(())
    });
    assert!(
        gpfq_wins * 10 >= total * 9,
        "gpfq beat msq in only {gpfq_wins}/{total} cases"
    );
}

#[test]
fn prop_gpfq_optimality_gap_vs_exhaustive() {
    // the greedy solution must never beat the exhaustive optimum, and on
    // overparameterized data stays within a small factor of it (median).
    let mut ratios = Vec::new();
    forall("gpfq vs exhaustive", 25, |g| {
        let m = g.dim(5);
        let n = 6 + g.dim(3); // 7..9: 3^9 = 19683 combos max
        let y = rand_matrix(g, m, n);
        let w: Vec<f32> = g.uniform_vec(n, -1.0, 1.0);
        let a = Alphabet::ternary(1.0);
        let (_, opt) = exhaustive_neuron(&y, &y, &w, a);
        let data = LayerData::first_layer(&y);
        let mut u = vec![0.0f32; m];
        let res = gpfq_neuron(&data, &w, a, &mut u);
        if res.err + 1e-4 < opt {
            return Err(format!("greedy {} beat optimum {}", res.err, opt));
        }
        if opt > 1e-3 {
            ratios.push(res.err / opt);
        }
        Ok(())
    });
    let med = gpfq::util::stats::median(&ratios);
    assert!(med < 8.0, "median greedy/optimal ratio {med}");
}

#[test]
fn prop_gpfq_permutation_covariance_under_shared_order() {
    // quantizing neuron columns is independent: permuting neurons permutes Q
    forall("neuron permutation covariance", 20, |g| {
        let m = g.dim(10);
        let n = g.dim(20).max(2);
        let k = 4;
        let y = rand_matrix(g, m, n);
        let w = Matrix::from_vec(n, k, g.uniform_vec(n * k, -1.0, 1.0));
        let a = Alphabet::ternary(1.0);
        let data = LayerData::first_layer(&y);
        let res = gpfq_layer(&data, &w, a);
        // reversed neuron order
        let mut w_rev = Matrix::zeros(n, k);
        for j in 0..k {
            w_rev.set_col(j, &w.col(k - 1 - j));
        }
        let res_rev = gpfq_layer(&data, &w_rev, a);
        for j in 0..k {
            if res.q.col(j) != res_rev.q.col(k - 1 - j) {
                return Err(format!("column {j} not permutation-covariant"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sigma_delta_bounded_state() {
    forall("sigma-delta state bound", 100, |g| {
        let m = *g.choice(&[2usize, 3, 4, 16]);
        let alpha = g.f32_in(0.2, 2.0);
        let a = Alphabet::new(alpha, m);
        let len = g.dim(300);
        let w: Vec<f32> = g.uniform_vec(len, -alpha, alpha);
        let (_, s) = sigma_delta(&w, a);
        prop_assert(
            s.abs() <= a.step() / 2.0 + 1e-4,
            format!("state {s} > step/2 {}", a.step() / 2.0),
        )
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_order_and_completeness() {
    forall("scheduler preserves order for any worker/cap combo", 30, |g| {
        let n = g.dim(64);
        let workers = g.usize_in(1, 8);
        let cap = g.usize_in(1, 16);
        let cfg = SchedulerConfig { workers, queue_cap: cap };
        let out: Vec<usize> =
            run_jobs(cfg, (0..n).collect(), |i, j| Ok::<_, ()>(i * 7 + j)).unwrap();
        prop_assert(
            out == (0..n).map(|j| j * 8).collect::<Vec<_>>(),
            format!("workers={workers} cap={cap} n={n}"),
        )
    });
}

#[test]
fn prop_pipeline_every_selected_layer_quantized_once() {
    forall("pipeline quantizes each selected layer exactly once", 8, |g| {
        let in_dim = 8 + g.dim(8);
        let h1 = 4 + g.dim(8);
        let h2 = 4 + g.dim(8);
        let net = mnist_mlp(g.usize_in(0, 1000) as u64, in_dim, &[h1, h2], 3);
        let x = rand_matrix(g, 20, in_dim);
        let out = quantize_network(&net, &x, &PipelineConfig { workers: g.usize_in(1, 4), ..Default::default() });
        let mut idxs: Vec<usize> = out.layer_reports.iter().map(|r| r.layer_index).collect();
        let expect = net.quantizable_layers();
        idxs.sort_unstable();
        prop_assert(idxs == expect, format!("{idxs:?} vs {expect:?}"))
    });
}

#[test]
fn prop_pipeline_msq_ignores_data() {
    // MSQ is data-free: different quantization data must give identical Q
    forall("msq pipeline data-independence", 8, |g| {
        let mut b = NetworkBuilder::new(Shape::Flat(12), g.usize_in(0, 100) as u64);
        b.dense(8, Activation::Relu).dense(3, Activation::None);
        let net = b.build();
        let x1 = rand_matrix(g, 16, 12);
        let x2 = rand_matrix(g, 16, 12);
        let cfg = PipelineConfig { method: Method::Msq, ..Default::default() };
        let a = quantize_network(&net, &x1, &cfg);
        let b2 = quantize_network(&net, &x2, &cfg);
        prop_assert(
            a.network.layers[0].weights().unwrap().data == b2.network.layers[0].weights().unwrap().data,
            "msq depended on data".to_string(),
        )
    });
}

// ---------------------------------------------------------------------------
// kernel bit-parity (nn::kernels)
// ---------------------------------------------------------------------------

#[test]
fn prop_tiled_gemm_bit_identical_to_naive() {
    forall("tiled GEMM == naive summation tree", 30, |g| {
        let m = g.usize_in(1, 12);
        let k = g.usize_in(1, 300);
        let n = g.usize_in(1, 40);
        let mut a = rand_matrix(g, m, k);
        // plant exact zeros: the canonical tree skips zero left coefficients
        for v in a.data.iter_mut() {
            if g.f32_in(0.0, 1.0) < 0.25 {
                *v = 0.0;
            }
        }
        let b = rand_matrix(g, k, n);
        let tiled = a.matmul(&b);
        let naive = a.matmul_naive(&b);
        let same = tiled.data.iter().zip(&naive.data).all(|(p, q)| p.to_bits() == q.to_bits());
        prop_assert(same, format!("matmul {m}x{k}x{n} diverged from naive"))
    });
}

#[test]
fn prop_tiled_gemm_tn_bit_identical_to_naive() {
    forall("tiled TN GEMM == naive summation tree", 30, |g| {
        let k = g.usize_in(1, 300);
        let m = g.usize_in(1, 24);
        let n = g.usize_in(1, 16);
        let mut at = rand_matrix(g, k, m);
        for v in at.data.iter_mut() {
            if g.f32_in(0.0, 1.0) < 0.25 {
                *v = 0.0;
            }
        }
        let b = rand_matrix(g, k, n);
        let tiled = at.matmul_tn(&b);
        let naive = at.matmul_tn_naive(&b);
        let same = tiled.data.iter().zip(&naive.data).all(|(p, q)| p.to_bits() == q.to_bits());
        prop_assert(same, format!("matmul_tn ({k}x{m})^T x {k}x{n} diverged from naive"))
    });
}

#[test]
fn prop_packed_matmul_bit_identical_to_decoded_gemm() {
    forall("packed GEMM == naive GEMM on decoded weights", 30, |g| {
        let m = *g.choice(&[2usize, 3, 4, 5, 8, 16, 31]);
        let alpha = g.f32_in(0.05, 3.0);
        let a = Alphabet::new(alpha, m);
        let rows = g.usize_in(1, 40); // N features
        let cols = g.usize_in(1, 12); // p neurons
        let batch = g.usize_in(1, 9);
        let levels: Vec<f32> = (0..rows * cols).map(|_| a.level(g.usize_in(0, m - 1))).collect();
        let w = Matrix::from_vec(rows, cols, levels);
        let p = PackedWeights::from_matrix(&w, a).expect("alphabet-valued weights must pack");
        let mut x = rand_matrix(g, batch, rows);
        for v in x.data.iter_mut() {
            if g.f32_in(0.0, 1.0) < 0.25 {
                *v = 0.0;
            }
        }
        let got = packed_matmul(&x, &p);
        let want = x.matmul_naive(&p.unpack());
        let same = got.data.iter().zip(&want.data).all(|(s, t)| s.to_bits() == t.to_bits());
        prop_assert(same, format!("packed {batch}x{rows}x{cols} (M={m}) diverged"))
    });
}

#[test]
fn prop_axpy_lanes_bit_identical_to_scalar() {
    // the lane-blocked kernel computes the same `out + a·b` two-rounding
    // op per element as a scalar loop — only the instruction schedule
    // differs.  Lengths straddle every LANES remainder (ragged tails).
    forall("axpy_lanes == scalar axpy", 100, |g| {
        let n = g.usize_in(1, 4 * LANES + 3);
        let a = if g.f32_in(0.0, 1.0) < 0.1 { 0.0 } else { g.f32_in(-2.0, 2.0) };
        let b: Vec<f32> = g.normal_vec(n);
        let init: Vec<f32> = g.normal_vec(n);
        let mut lane = init.clone();
        axpy_lanes(a, &b, &mut lane);
        let mut scalar = init;
        for (o, &bv) in scalar.iter_mut().zip(&b) {
            *o += a * bv;
        }
        let same = lane.iter().zip(&scalar).all(|(p, q)| p.to_bits() == q.to_bits());
        prop_assert(same, format!("axpy_lanes len {n} a={a} diverged"))
    });
}

#[test]
fn prop_fused_forward_bit_identical_to_unfused_mlp() {
    // the fused epilogue (bias → activation → BN affine applied per
    // cache-hot tile) vs the frozen per-layer oracle, on MLPs whose
    // builder interleaves dense+BN — float weights and packed alike
    forall("fused forward == unfused oracle (MLP, float + packed)", 8, |g| {
        let in_dim = 8 + g.dim(8);
        let h1 = 4 + g.dim(8);
        let net = mnist_mlp(g.usize_in(0, 1000) as u64, in_dim, &[h1], 3);
        let xq = rand_matrix(g, 12, in_dim);
        let x = rand_matrix(g, g.usize_in(1, 9), in_dim);
        let fused = net.forward(&x);
        let oracle = net.forward_unfused(&x);
        let same = fused.data.iter().zip(&oracle.data).all(|(p, q)| p.to_bits() == q.to_bits());
        if !same {
            return Err("float fused forward diverged from unfused".to_string());
        }
        let out = quantize_network(&net, &xq, &PipelineConfig::default());
        let packed = pack_network(&out.network, &hints_from_outcome(&out));
        let fused = packed.forward(&x);
        let oracle = packed.forward_unfused(&x);
        let same = fused.data.iter().zip(&oracle.data).all(|(p, q)| p.to_bits() == q.to_bits());
        prop_assert(same, "packed fused forward diverged from unfused".to_string())
    });
}

#[test]
fn prop_fused_forward_bit_identical_to_unfused_cnn() {
    // conv layers fuse bias+activation into the pre-fold GEMM epilogue
    // (and BN only when channels divide the GEMM width); the CNN builder
    // covers conv, pool, BN and the dense head in one net
    forall("fused forward == unfused oracle (CNN)", 5, |g| {
        let img = ImgShape { h: 6 + g.dim(3), w: 6 + g.dim(3), c: *g.choice(&[1usize, 3]) };
        let net = cifar_cnn(g.usize_in(0, 1000) as u64, img, &[*g.choice(&[2usize, 4])], 8, 3);
        let x = rand_matrix(g, g.usize_in(1, 5), img.len());
        let fused = net.forward(&x);
        let oracle = net.forward_unfused(&x);
        let same = fused.data.iter().zip(&oracle.data).all(|(p, q)| p.to_bits() == q.to_bits());
        prop_assert(same, "CNN fused forward diverged from unfused".to_string())
    });
}

#[test]
fn prop_sharded_forward_bit_identical_across_worker_counts() {
    // row-sharded batch execution must be invisible in the bits for every
    // worker count and every batch size (ragged vs chunking included)
    forall("forward_sharded == serial forward for workers 1/2/4", 6, |g| {
        let in_dim = 6 + g.dim(6);
        let net = mnist_mlp(g.usize_in(0, 1000) as u64, in_dim, &[5], 3);
        let x = rand_matrix(g, g.usize_in(1, 11), in_dim);
        let serial = net.forward(&x);
        for workers in [1usize, 2, 4] {
            let sharded = forward_sharded(&net, &x, workers);
            let same =
                sharded.data.iter().zip(&serial.data).all(|(p, q)| p.to_bits() == q.to_bits());
            if !same {
                return Err(format!("sharded forward diverged at {workers} workers"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gpfq_scale_equivariance() {
    // Assumption 2 discussion: quantizing c*w with alphabet radius c*alpha
    // gives c * (quantization of w with radius alpha).
    forall("scale equivariance", 25, |g| {
        let m = g.dim(10);
        let n = g.dim(24).max(2);
        let y = rand_matrix(g, m, n);
        let w: Vec<f32> = g.uniform_vec(n, -1.0, 1.0);
        let c = g.f32_in(0.25, 4.0);
        let data = LayerData::first_layer(&y);
        let mut u = vec![0.0f32; m];
        let q1 = gpfq_neuron(&data, &w, Alphabet::ternary(1.0), &mut u).q;
        let wc: Vec<f32> = w.iter().map(|v| v * c).collect();
        let q2 = gpfq_neuron(&data, &wc, Alphabet::ternary(c), &mut u).q;
        for t in 0..n {
            if (q1[t] * c - q2[t]).abs() > 1e-3 * c {
                return Err(format!("t={t}: {} * {c} != {}", q1[t], q2[t]));
            }
        }
        Ok(())
    });
}
