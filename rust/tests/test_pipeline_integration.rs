//! Integration tests: the full coordinator pipeline over trained networks
//! of every architecture family, checking the paper's qualitative results
//! end to end (train → quantize → evaluate).

use gpfq::coordinator::pipeline::{quantize_network, verify_alphabet, Method, PipelineConfig};
use gpfq::coordinator::sweep::{sweep, SweepConfig};
use gpfq::data::synth::{generate, SynthSpec};
use gpfq::data::Dataset;
use gpfq::eval::metrics::{accuracy, topk_accuracy};
use gpfq::nn::conv::ImgShape;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, vgg_like, Network};
use gpfq::train::{train, TrainConfig};

fn spec(classes: usize, shape: ImgShape, seed: u64) -> SynthSpec {
    SynthSpec { classes, shape, blobs: 5, noise: 0.3, max_shift: 1, seed }
}

fn train_net(net: &mut Network, data: &Dataset, epochs: usize) {
    let cfg = TrainConfig { epochs, batch: 32, lr: 0.04, momentum: 0.9, seed: 3, verbose: false };
    train(net, data, &cfg);
}

#[test]
fn mlp_full_cycle_ternary() {
    let s = spec(4, ImgShape { h: 10, w: 10, c: 1 }, 31);
    let tr = generate(&s, 400, 0, false);
    let te = generate(&s, 200, 1, false);
    let mut net = mnist_mlp(3, 100, &[48, 24], 4);
    train_net(&mut net, &tr, 10);
    let analog = accuracy(&net, &te);
    assert!(analog > 0.8, "analog acc {analog}");

    let out = quantize_network(&net, &tr.x.rows_slice(0, 200), &PipelineConfig { c_alpha: 3.0, ..Default::default() });
    assert!(verify_alphabet(&out));
    let q = accuracy(&out.network, &te);
    assert!(q > analog - 0.2, "ternary GPFQ acc {q} vs analog {analog}");
    assert_eq!(out.layer_reports.len(), 3);
    // weights were replaced, biases kept float
    for rep in &out.layer_reports {
        assert!(rep.seconds >= 0.0 && rep.neurons > 0);
    }
}

#[test]
fn cnn_full_cycle_4bit() {
    let img = ImgShape { h: 12, w: 12, c: 1 };
    let s = spec(3, img, 32);
    let tr = generate(&s, 300, 0, false);
    let te = generate(&s, 150, 1, false);
    let mut net = cifar_cnn(4, img, &[4], 24, 3);
    train_net(&mut net, &tr, 8);
    let analog = accuracy(&net, &te);
    assert!(analog > 0.7, "analog acc {analog}");

    let cfg = PipelineConfig { levels: 16, c_alpha: 4.0, ..Default::default() };
    let out = quantize_network(&net, &tr.x.rows_slice(0, 100), &cfg);
    assert!(verify_alphabet(&out));
    // conv + dense layers all quantized
    assert_eq!(out.layer_reports.len(), net.quantizable_layers().len());
    let q = accuracy(&out.network, &te);
    assert!(q > analog - 0.15, "4-bit acc {q} vs analog {analog}");
}

#[test]
fn vgg_fc_only_protocol() {
    let img = ImgShape { h: 12, w: 12, c: 1 };
    let s = spec(3, img, 33);
    let tr = generate(&s, 250, 0, false);
    let te = generate(&s, 120, 1, false);
    let mut net = vgg_like(5, img, &[4], &[64, 32], 3);
    train_net(&mut net, &tr, 8);

    let cfg = PipelineConfig { fc_only: true, c_alpha: 3.0, ..Default::default() };
    let out = quantize_network(&net, &tr.x.rows_slice(0, 100), &cfg);
    // only dense layers quantized; conv kernels untouched
    assert!(out.layer_reports.iter().all(|r| r.label.starts_with("dense")));
    for (i, layer) in out.network.layers.iter().enumerate() {
        if matches!(layer, gpfq::nn::Layer::Conv { .. }) {
            assert_eq!(
                layer.weights().unwrap().data,
                net.layers[i].weights().unwrap().data,
                "conv layer {i} must be unchanged"
            );
        }
    }
    // top-5 >= top-1 sanity on multiclass
    let t1 = topk_accuracy(&out.network, &te, 1);
    let t3 = topk_accuracy(&out.network, &te, 3);
    assert!(t3 >= t1);
}

#[test]
fn gpfq_dominates_msq_in_layer_error_on_every_arch() {
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let s = spec(3, img, 34);
    let tr = generate(&s, 200, 0, false);
    for (name, mut net) in [
        ("mlp", mnist_mlp(6, 100, &[32], 3)),
        ("cnn", cifar_cnn(7, img, &[4], 16, 3)),
    ] {
        train_net(&mut net, &tr, 5);
        let x = tr.x.rows_slice(0, 100);
        let g = quantize_network(&net, &x, &PipelineConfig { c_alpha: 3.0, ..Default::default() });
        let m = quantize_network(
            &net,
            &x,
            &PipelineConfig { method: Method::Msq, c_alpha: 3.0, ..Default::default() },
        );
        for (gr, mr) in g.layer_reports.iter().zip(&m.layer_reports) {
            assert!(
                gr.fro_err <= mr.fro_err + 1e-9,
                "{name} layer {}: gpfq {} > msq {}",
                gr.label,
                gr.fro_err,
                mr.fro_err
            );
        }
    }
}

#[test]
fn sweep_matches_single_runs() {
    let s = spec(3, ImgShape { h: 8, w: 8, c: 1 }, 35);
    let tr = generate(&s, 200, 0, false);
    let te = generate(&s, 100, 1, false);
    let mut net = mnist_mlp(8, 64, &[24], 3);
    train_net(&mut net, &tr, 6);
    let x = tr.x.rows_slice(0, 100);
    let res = sweep(
        &net,
        &x,
        &te,
        &SweepConfig { levels: vec![3], c_alphas: vec![2.0], methods: vec![Method::Gpfq], ..Default::default() },
    );
    let single = quantize_network(&net, &x, &PipelineConfig { c_alpha: 2.0, ..Default::default() });
    let acc_single = accuracy(&single.network, &te);
    assert!((res.points[0].top1 - acc_single).abs() < 1e-9, "sweep must reproduce single runs exactly");
}

#[test]
fn progressive_checkpoints_monotone_layer_count() {
    let s = spec(3, ImgShape { h: 8, w: 8, c: 1 }, 36);
    let tr = generate(&s, 150, 0, false);
    let mut net = mnist_mlp(9, 64, &[24, 12], 3);
    train_net(&mut net, &tr, 4);
    let out = quantize_network(
        &net,
        &tr.x.rows_slice(0, 80),
        &PipelineConfig { capture_checkpoints: true, ..Default::default() },
    );
    assert_eq!(out.checkpoints.len(), 3);
    // checkpoint k has exactly k quantized (ternary) layers
    for (k, ck) in out.checkpoints.iter().enumerate() {
        let quantized = ck
            .quantizable_layers()
            .into_iter()
            .filter(|&i| {
                let w = ck.layers[i].weights().unwrap();
                let mut vals: Vec<i64> = w.data.iter().map(|&v| (v * 1e6).round() as i64).collect();
                vals.sort_unstable();
                vals.dedup();
                vals.len() <= 3
            })
            .count();
        assert!(quantized >= k + 1, "checkpoint {k} has {quantized} quantized layers");
    }
}
