//! Activation-engine guarantees, pinned hard:
//!
//! 1. **Golden parity** — the zero-copy two-stream engine produces
//!    quantized networks *bit-identical* to the frozen pre-refactor
//!    pipeline ([`gpfq::coordinator::reference`]) on an MLP and a conv net,
//!    seeded, across worker counts, with and without bias augmentation
//!    (the PR-1 determinism contract extended through the refactor).
//! 2. **im2col economy** — conv layers build their patch matrix at most
//!    once per layer per stream (and only once total while the streams
//!    still share a prefix), measured through the process-wide invocation
//!    counter under a serial lock.
//!
//! The lock exists because `cargo test` runs tests of one binary
//! concurrently and the im2col counter is process-global: every test here
//! that runs conv pipelines holds it, so counter deltas are exact.

use std::sync::Mutex;

use gpfq::coordinator::pipeline::{
    quantize_network, verify_alphabet, Method, PipelineConfig,
};
use gpfq::coordinator::reference::reference_quantize_network;
use gpfq::data::rng::Pcg;
use gpfq::nn::conv::{im2col_invocations, ImgShape};
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{cifar_cnn, mnist_mlp, vgg_like, Layer, Network};

static SERIAL: Mutex<()> = Mutex::new(());

fn rand_input(seed: u64, rows: usize, cols: usize) -> Matrix {
    let mut rng = Pcg::seed(seed);
    Matrix::from_vec(rows, cols, rng.normal_vec(rows * cols))
}

/// Assert two networks agree bit for bit in every weight and bias.
fn assert_networks_identical(a: &Network, b: &Network, tag: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{tag}: layer count");
    for (i, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        match (la.weights(), lb.weights()) {
            (Some(wa), Some(wb)) => assert_eq!(wa.data, wb.data, "{tag}: layer {i} weights"),
            (None, None) => {}
            _ => panic!("{tag}: layer {i} kind mismatch"),
        }
        if let (Layer::Dense { b: ba, .. }, Layer::Dense { b: bb, .. }) = (la, lb) {
            assert_eq!(ba, bb, "{tag}: layer {i} bias");
        }
    }
}

fn assert_parity(net: &Network, x: &Matrix, cfg: &PipelineConfig, tag: &str) {
    let engine = quantize_network(net, x, cfg);
    let oracle = reference_quantize_network(net, x, cfg).unwrap();
    assert_networks_identical(&engine.network, &oracle.network, tag);
    assert_eq!(engine.layer_reports.len(), oracle.layer_reports.len(), "{tag}: report count");
    for (e, o) in engine.layer_reports.iter().zip(&oracle.layer_reports) {
        assert_eq!(e.layer_index, o.layer_index, "{tag}");
        assert_eq!(e.label, o.label, "{tag}");
        assert_eq!(e.alpha, o.alpha, "{tag}: alpha");
        assert_eq!(e.fro_err, o.fro_err, "{tag}: fro_err must be bit-identical");
        assert_eq!(e.median_rel_err, o.median_rel_err, "{tag}: median_rel_err");
        let dims = (e.neurons, e.n_features, e.m_samples);
        assert_eq!(dims, (o.neurons, o.n_features, o.m_samples), "{tag}");
    }
    assert_eq!(engine.checkpoints.len(), oracle.checkpoints.len(), "{tag}: checkpoints");
    for (k, (ce, co)) in engine.checkpoints.iter().zip(&oracle.checkpoints).enumerate() {
        assert_networks_identical(ce, co, &format!("{tag}: checkpoint {k}"));
    }
}

#[test]
fn golden_parity_mlp_multi_worker() {
    let net = mnist_mlp(41, 40, &[32, 16], 4);
    let x = rand_input(7, 60, 40);
    for workers in [1usize, 3, 8] {
        assert_parity(
            &net,
            &x,
            &PipelineConfig { workers, c_alpha: 2.5, ..Default::default() },
            &format!("mlp workers={workers}"),
        );
    }
    // 4-bit alphabet and MSQ take the same staged path
    assert_parity(
        &net,
        &x,
        &PipelineConfig { levels: 16, c_alpha: 4.0, ..Default::default() },
        "mlp 4-bit",
    );
    assert_parity(
        &net,
        &x,
        &PipelineConfig { method: Method::Msq, ..Default::default() },
        "mlp msq",
    );
}

#[test]
fn golden_parity_mlp_bias_augmentation() {
    let net = mnist_mlp(42, 24, &[16], 3);
    let x = rand_input(8, 40, 24);
    for workers in [1usize, 4] {
        assert_parity(
            &net,
            &x,
            &PipelineConfig { quantize_bias: true, c_alpha: 3.0, workers, ..Default::default() },
            &format!("mlp bias workers={workers}"),
        );
    }
}

#[test]
fn golden_parity_conv_net_multi_worker() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let net = cifar_cnn(43, img, &[3], 12, 3); // conv, bn, conv, mp, bn, dense, bn, dense
    let x = rand_input(9, 8, img.len());
    for workers in [1usize, 4] {
        assert_parity(
            &net,
            &x,
            &PipelineConfig { workers, c_alpha: 2.0, ..Default::default() },
            &format!("cnn workers={workers}"),
        );
    }
    // checkpoints ride through the staged engine identically
    assert_parity(
        &net,
        &x,
        &PipelineConfig { capture_checkpoints: true, ..Default::default() },
        "cnn checkpoints",
    );
}

#[test]
fn golden_parity_vgg_fc_only_and_max_layers() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let net = vgg_like(44, img, &[3], &[24, 12], 3);
    let x = rand_input(10, 6, img.len());
    assert_parity(
        &net,
        &x,
        &PipelineConfig { fc_only: true, c_alpha: 3.0, ..Default::default() },
        "vgg fc_only",
    );
    for k in [0usize, 1, 2] {
        assert_parity(
            &net,
            &x,
            &PipelineConfig { max_layers: Some(k), ..Default::default() },
            &format!("vgg max_layers={k}"),
        );
    }
}

#[test]
fn conv_im2col_at_most_once_per_layer_per_stream() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 10, w: 10, c: 1 };
    let net = cifar_cnn(45, img, &[3], 12, 3); // layers: conv, bn, conv, mp, bn, dense, bn, dense
    let x = rand_input(11, 6, img.len());

    let before = im2col_invocations();
    let out = quantize_network(&net, &x, &PipelineConfig::default());
    let engine_calls = im2col_invocations() - before;
    assert_eq!(out.layer_reports.len(), 4);

    // conv #1 is quantized while the streams still share their prefix: ONE
    // patch build serves the quantizer and both forward GEMMs.  conv #2 runs
    // after divergence: one build per stream.  Dense layers never im2col.
    assert_eq!(
        engine_calls, 3,
        "engine must build im2col once per conv layer per distinct stream (1 shared + 2 diverged)"
    );

    // ceiling check from the satellite spec: never more than once per layer
    // per stream
    let conv_layers = 2;
    let streams = 2;
    assert!(engine_calls <= conv_layers * streams);

    // the oracle shows what the refactor removed: 2 quantization_data + 2
    // forward im2cols per conv layer = 8
    let before_ref = im2col_invocations();
    let _ = reference_quantize_network(&net, &x, &PipelineConfig::default()).unwrap();
    let oracle_calls = im2col_invocations() - before_ref;
    assert_eq!(oracle_calls, 8, "oracle im2col count changed — was the reference edited?");
}

#[test]
fn fc_only_conv_forward_im2cols_once_while_shared() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = vgg_like(46, img, &[2], &[12], 3); // conv, mp, dense, bn, dense
    let x = rand_input(12, 5, img.len());
    let before = im2col_invocations();
    let _ = quantize_network(&net, &x, &PipelineConfig { fc_only: true, ..Default::default() });
    // the unquantized conv layer is crossed while the streams still share:
    // exactly one forward im2col for both streams
    assert_eq!(im2col_invocations() - before, 1);
}

#[test]
fn engine_reports_carry_timing_splits_and_peak_bytes() {
    let _guard = SERIAL.lock().unwrap();
    let img = ImgShape { h: 8, w: 8, c: 1 };
    let net = cifar_cnn(47, img, &[2], 8, 3);
    let x = rand_input(13, 5, img.len());
    let out = quantize_network(&net, &x, &PipelineConfig::default());
    assert!(verify_alphabet(&out));
    for rep in &out.layer_reports {
        assert!(rep.peak_resident_bytes > 0, "{}: peak bytes missing", rep.label);
        assert!(rep.im2col_seconds >= 0.0 && rep.gemm_seconds >= 0.0);
        assert!(rep.quantize_seconds >= 0.0);
        if rep.label.starts_with("conv") {
            // a conv layer's peak must at least cover one patch matrix
            let patch_bytes = rep.n_features * rep.m_samples * 4;
            assert!(
                rep.peak_resident_bytes >= patch_bytes,
                "{}: peak {} < one patch matrix {}",
                rep.label,
                rep.peak_resident_bytes,
                patch_bytes
            );
        }
    }
}
