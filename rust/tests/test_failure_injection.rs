//! Failure injection: corrupt artifacts, broken manifests, mid-flight job
//! errors — the coordinator must fail loudly and cleanly, never silently
//! produce wrong numbers.

use std::io::Write;

use gpfq::coordinator::scheduler::{run_jobs, SchedulerConfig};
use gpfq::nn::matrix::Matrix;
use gpfq::runtime::{Arg, Manifest, Runtime};

fn write_file(dir: &std::path::Path, name: &str, contents: &str) {
    let mut f = std::fs::File::create(dir.join(name)).unwrap();
    f.write_all(contents.as_bytes()).unwrap();
}

fn tempdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gpfq_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn manifest_garbage_is_an_error_not_a_panic() {
    let dir = tempdir("garbage_manifest");
    write_file(&dir, "manifest.json", "{ not json");
    assert!(Manifest::load(&dir).is_err());
    write_file(&dir, "manifest.json", r#"{"version": 9}"#);
    assert!(Manifest::load(&dir).is_err(), "wrong version must be rejected");
    write_file(&dir, "manifest.json", r#"{"version":1,"artifacts":[{"kind":"gpfq"}]}"#);
    assert!(Manifest::load(&dir).is_err(), "artifact without name must be rejected");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_hlo_file_detected_by_validation() {
    let dir = tempdir("missing_hlo");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"ghost","file":"ghost.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[4,4],"dtype":"f32"}],
             "outputs":[{"shape":[4,4],"dtype":"f32"}],"meta":{}}]}"#,
    );
    let man = Manifest::load(&dir).unwrap();
    assert!(man.validate_files().is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_hlo_text_fails_at_execute_with_context() {
    let dir = tempdir("corrupt_hlo");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"bad","file":"bad.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[2,2],"dtype":"f32"}],
             "outputs":[{"shape":[2,2],"dtype":"f32"}],"meta":{}}]}"#,
    );
    write_file(&dir, "bad.hlo.txt", "HloModule utterly_broken\n%%%garbage%%%\n");
    let rt = Runtime::new(&dir).expect("runtime builds; compile is lazy");
    let w = Matrix::zeros(2, 2);
    let err = rt.execute("bad", &[Arg::Mat(&w)]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "error should name the artifact: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wrong_arity_and_shape_rejected_before_execution() {
    // use the real artifacts when present; otherwise a synthetic manifest
    // with a file that never needs to compile (validation fires first).
    let dir = tempdir("arity");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"a","file":"a.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[4,4],"dtype":"f32"},
                        {"name":"alpha","shape":[],"dtype":"f32"}],
             "outputs":[{"shape":[4,4],"dtype":"f32"}],"meta":{}}]}"#,
    );
    write_file(&dir, "a.hlo.txt", "never compiled");
    let rt = Runtime::new(&dir).unwrap();
    let w = Matrix::zeros(4, 4);
    // arity
    let err = rt.execute("a", &[Arg::Mat(&w)]).unwrap_err();
    assert!(format!("{err}").contains("expected 2 args"));
    // shape
    let small = Matrix::zeros(2, 2);
    let err = rt.execute("a", &[Arg::Mat(&small), Arg::Scalar(1.0)]).unwrap_err();
    assert!(format!("{err}").contains("expects"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scheduler_survives_panicking_free_errors_under_load() {
    // stress: many jobs, several of which fail, across queue pressure
    for cap in [1usize, 2, 64] {
        let cfg = SchedulerConfig { workers: 4, queue_cap: cap };
        let res: Result<Vec<usize>, String> = run_jobs(cfg, (0..500).collect(), |_, j| {
            if j % 97 == 13 {
                Err(format!("fail {j}"))
            } else {
                Ok(j)
            }
        });
        let err = res.unwrap_err();
        assert!(err.starts_with("fail"), "cap={cap}: {err}");
    }
}

#[test]
fn scheduler_many_workers_few_jobs() {
    let cfg = SchedulerConfig { workers: 32, queue_cap: 1 };
    let out: Vec<usize> = run_jobs(cfg, vec![7, 8], |i, j| Ok::<_, ()>(i + j)).unwrap();
    assert_eq!(out, vec![7, 9]);
}

#[test]
fn model_file_corruption_detected() {
    use gpfq::nn::serialize::{load_file, save_file, AlphabetHints};
    let dir = tempdir("model_corrupt");
    let net = gpfq::nn::mnist_mlp(1, 12, &[6], 3);
    let path = dir.join("m.gpfq");
    save_file(&net, &AlphabetHints::new(), &path).unwrap();
    // flip bytes in the header region
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[0] = b'X';
    std::fs::write(&path, &bytes).unwrap();
    assert!(load_file(&path).is_err());
    // truncate mid-layer
    save_file(&net, &AlphabetHints::new(), &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() * 2 / 3]).unwrap();
    assert!(load_file(&path).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Service-level failure injection for the distributed sweep: kill or
/// hang a worker mid-unit and prove the coordinator re-queues the unit
/// (with an explicit assignment receipt), converges on the survivors,
/// and merges an artifact **bit-identical** to the healthy in-process
/// sweep — or, when no worker can finish the work, fails loudly instead
/// of silently returning partial numbers.
mod dist_service {
    use std::net::{SocketAddr, TcpListener};
    use std::thread::JoinHandle;
    use std::time::Duration;

    use gpfq::coordinator::{
        dist_sweep_trials, run_worker, sweep_trials, DistConfig, Method, SweepConfig,
        SweepResult, TrialSet, UnitOutcome, WorkerFault,
    };
    use gpfq::data::synth::{generate, SynthSpec};
    use gpfq::data::Dataset;
    use gpfq::nn::conv::ImgShape;
    use gpfq::nn::network::{mnist_mlp, Network};
    use gpfq::serve::HttpClient;
    use gpfq::train::{train, TrainConfig};

    const N_QUANT: usize = 60;
    const N_TRIALS: usize = 2;
    const TRIAL_SEED: u64 = 7;

    fn trained_mlp() -> (Network, Dataset, Dataset) {
        let spec = SynthSpec {
            classes: 3,
            shape: ImgShape { h: 8, w: 8, c: 1 },
            blobs: 4,
            noise: 0.15,
            max_shift: 1,
            seed: 21,
        };
        let tr = generate(&spec, 240, 0, false);
        let te = generate(&spec, 120, 1, false);
        let mut net = mnist_mlp(2, 64, &[32], 3);
        train(
            &mut net,
            &tr,
            &TrainConfig { epochs: 6, batch: 32, lr: 0.05, momentum: 0.9, seed: 2, verbose: false },
        );
        (net, tr, te)
    }

    fn grid() -> SweepConfig {
        SweepConfig {
            levels: vec![3],
            c_alphas: vec![2.0, 4.0],
            methods: vec![Method::Gpfq, Method::Msq],
            fc_only: false,
            topk: false,
            workers: 2,
            chunk_cells: Some(2),
        }
    }

    fn spawn_worker(
        net: &Network,
        tr: &Dataset,
        te: &Dataset,
        cfg: &SweepConfig,
        fault: WorkerFault,
    ) -> (SocketAddr, JoinHandle<usize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (net, tr, te, cfg) = (net.clone(), tr.clone(), te.clone(), cfg.clone());
        let handle = std::thread::spawn(move || {
            let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
            run_worker(listener, &net, &trials, &te, &cfg, fault).expect("worker serves")
        });
        (addr, handle)
    }

    /// Scores/stats/peak only — the wall-clock exemption is covered by
    /// the full field-by-field pin in `test_dist_sweep.rs`.
    fn assert_scores_bit_identical(a: &SweepResult, b: &SweepResult) {
        assert_eq!(a.peak_resident_bytes, b.peak_resident_bytes);
        assert_eq!(a.points.len(), b.points.len());
        for (p, q) in a.points.iter().zip(&b.points) {
            assert_eq!(p.top1.to_bits(), q.top1.to_bits(), "trial-0 top1");
            assert_eq!(p.top1_trials.len(), q.top1_trials.len());
            for (x, y) in p.top1_trials.iter().zip(&q.top1_trials) {
                assert_eq!(x.to_bits(), y.to_bits(), "trial vector");
            }
            assert_eq!(p.top1_stats.mean.to_bits(), q.top1_stats.mean.to_bits(), "mean");
            assert_eq!(p.top1_stats.std.to_bits(), q.top1_stats.std.to_bits(), "std");
        }
    }

    /// Kill a worker on its FIRST unit (connection dropped mid-request):
    /// the unit is re-queued with a `Failed` receipt and re-runs on the
    /// survivor; the merged artifact is bit-identical to the healthy
    /// in-process sweep.
    #[test]
    fn worker_death_mid_unit_requeues_and_converges_bit_identically() {
        let (net, tr, te) = trained_mlp();
        let cfg = grid();
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        let baseline = sweep_trials(&net, &trials, &te, &cfg);

        let (addr_dying, h_dying) =
            spawn_worker(&net, &tr, &te, &cfg, WorkerFault { fail_after: Some(0), hang: None });
        // the survivor dwells 300ms on its first unit (well under the
        // 120s timeout) so the dying worker's driver is guaranteed to
        // pop a unit before the queue drains — the death always fires
        let dwell = WorkerFault { fail_after: None, hang: Some((0, Duration::from_millis(300))) };
        let (addr_ok, h_ok) = spawn_worker(&net, &tr, &te, &cfg, dwell);
        let dcfg = DistConfig::new(vec![addr_dying, addr_ok]);
        let out = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg)
            .expect("the survivor finishes the sweep");

        assert_scores_bit_identical(&baseline, &out.result);
        assert_eq!(out.requeues, 1, "the dropped unit is re-queued exactly once");
        let failed: Vec<_> = out
            .assignments
            .iter()
            .filter(|a| a.outcome == UnitOutcome::Failed)
            .collect();
        assert_eq!(failed.len(), 1, "one explicit failure receipt");
        assert_eq!(failed[0].worker, 0, "the receipt names the dead worker");
        assert_eq!(failed[0].attempt, 0);
        // the same unit later completed on a higher attempt
        assert!(
            out.assignments.iter().any(|a| a.unit == failed[0].unit
                && a.outcome == UnitOutcome::Done
                && a.attempt == 1),
            "the re-queued unit must complete on attempt 1"
        );
        assert_eq!(out.worker_units, vec![0, 4], "the survivor served everything");
        assert_eq!(h_dying.join().unwrap(), 0, "the dying worker completed nothing");
        assert_eq!(h_ok.join().unwrap(), 4);
    }

    /// Hang a worker past the unit timeout: the unit is re-queued with a
    /// `TimedOut` receipt and the sweep converges bit-identically on the
    /// healthy worker.
    #[test]
    fn worker_hang_times_out_requeues_and_converges_bit_identically() {
        let (net, tr, te) = trained_mlp();
        let cfg = grid();
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        let baseline = sweep_trials(&net, &trials, &te, &cfg);

        let hang = WorkerFault { fail_after: None, hang: Some((0, Duration::from_secs(4))) };
        let (addr_hung, h_hung) = spawn_worker(&net, &tr, &te, &cfg, hang);
        // the healthy worker dwells 300ms on its first unit so the hung
        // worker's driver is guaranteed a unit before the queue drains
        let dwell = WorkerFault { fail_after: None, hang: Some((0, Duration::from_millis(300))) };
        let (addr_ok, h_ok) = spawn_worker(&net, &tr, &te, &cfg, dwell);
        let mut dcfg = DistConfig::new(vec![addr_hung, addr_ok]);
        dcfg.unit_timeout = Duration::from_secs(1);
        let out = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg)
            .expect("the healthy worker finishes the sweep");

        assert_scores_bit_identical(&baseline, &out.result);
        assert_eq!(out.requeues, 1, "the timed-out unit is re-queued exactly once");
        assert!(
            out.assignments
                .iter()
                .any(|a| a.worker == 0 && a.outcome == UnitOutcome::TimedOut),
            "an explicit TimedOut receipt names the hung worker"
        );
        assert_eq!(out.worker_units, vec![0, 4], "the healthy worker served everything");
        assert_eq!(h_ok.join().unwrap(), 4);
        // the hung worker wakes up, finds its coordinator gone, and goes
        // back to accepting; shut it down by hand so the thread exits
        let mut client = HttpClient::connect(addr_hung).unwrap();
        let (status, _) = client.request("POST", "/shutdown", None).unwrap();
        assert_eq!(status, 200);
        h_hung.join().unwrap();
    }

    /// Every worker dead with work remaining: the sweep must stall out
    /// LOUDLY (completed != total), never return a partial artifact.
    #[test]
    fn all_workers_dead_stalls_loudly_not_silently() {
        let (net, tr, te) = trained_mlp();
        let cfg = grid();
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        let (addr, handle) =
            spawn_worker(&net, &tr, &te, &cfg, WorkerFault { fail_after: Some(0), hang: None });
        let err = dist_sweep_trials(&net, &trials, &te, &cfg, &DistConfig::new(vec![addr]))
            .expect_err("no live workers must be an error");
        let msg = format!("{err:#}");
        assert!(msg.contains("stalled"), "the stall is named: {msg}");
        assert_eq!(handle.join().unwrap(), 0);
    }

    /// A unit that exhausts its retry budget fails the sweep loudly with
    /// the unit named in the error.
    #[test]
    fn retry_budget_exhaustion_fails_loudly() {
        let (net, tr, te) = trained_mlp();
        let cfg = grid();
        let trials = TrialSet::draw(&tr.x, N_QUANT, N_TRIALS, TRIAL_SEED);
        let (addr, handle) =
            spawn_worker(&net, &tr, &te, &cfg, WorkerFault { fail_after: Some(0), hang: None });
        let mut dcfg = DistConfig::new(vec![addr]);
        dcfg.max_retries = 0;
        let err = dist_sweep_trials(&net, &trials, &te, &cfg, &dcfg)
            .expect_err("a zero-retry budget must fail on the first death");
        let msg = format!("{err:#}");
        assert!(msg.contains("failed on attempt"), "the exhausted unit is named: {msg}");
        assert_eq!(handle.join().unwrap(), 0);
    }
}
