//! Failure injection: corrupt artifacts, broken manifests, mid-flight job
//! errors — the coordinator must fail loudly and cleanly, never silently
//! produce wrong numbers.

use std::io::Write;

use gpfq::coordinator::scheduler::{run_jobs, SchedulerConfig};
use gpfq::nn::matrix::Matrix;
use gpfq::runtime::{Arg, Manifest, Runtime};

fn write_file(dir: &std::path::Path, name: &str, contents: &str) {
    let mut f = std::fs::File::create(dir.join(name)).unwrap();
    f.write_all(contents.as_bytes()).unwrap();
}

fn tempdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gpfq_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn manifest_garbage_is_an_error_not_a_panic() {
    let dir = tempdir("garbage_manifest");
    write_file(&dir, "manifest.json", "{ not json");
    assert!(Manifest::load(&dir).is_err());
    write_file(&dir, "manifest.json", r#"{"version": 9}"#);
    assert!(Manifest::load(&dir).is_err(), "wrong version must be rejected");
    write_file(&dir, "manifest.json", r#"{"version":1,"artifacts":[{"kind":"gpfq"}]}"#);
    assert!(Manifest::load(&dir).is_err(), "artifact without name must be rejected");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_hlo_file_detected_by_validation() {
    let dir = tempdir("missing_hlo");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"ghost","file":"ghost.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[4,4],"dtype":"f32"}],
             "outputs":[{"shape":[4,4],"dtype":"f32"}],"meta":{}}]}"#,
    );
    let man = Manifest::load(&dir).unwrap();
    assert!(man.validate_files().is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_hlo_text_fails_at_execute_with_context() {
    let dir = tempdir("corrupt_hlo");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"bad","file":"bad.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[2,2],"dtype":"f32"}],
             "outputs":[{"shape":[2,2],"dtype":"f32"}],"meta":{}}]}"#,
    );
    write_file(&dir, "bad.hlo.txt", "HloModule utterly_broken\n%%%garbage%%%\n");
    let rt = Runtime::new(&dir).expect("runtime builds; compile is lazy");
    let w = Matrix::zeros(2, 2);
    let err = rt.execute("bad", &[Arg::Mat(&w)]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "error should name the artifact: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wrong_arity_and_shape_rejected_before_execution() {
    // use the real artifacts when present; otherwise a synthetic manifest
    // with a file that never needs to compile (validation fires first).
    let dir = tempdir("arity");
    write_file(
        &dir,
        "manifest.json",
        r#"{"version":1,"block_b":4,"mq":8,"artifacts":[
            {"name":"a","file":"a.hlo.txt","kind":"msq",
             "params":[{"name":"W","shape":[4,4],"dtype":"f32"},
                        {"name":"alpha","shape":[],"dtype":"f32"}],
             "outputs":[{"shape":[4,4],"dtype":"f32"}],"meta":{}}]}"#,
    );
    write_file(&dir, "a.hlo.txt", "never compiled");
    let rt = Runtime::new(&dir).unwrap();
    let w = Matrix::zeros(4, 4);
    // arity
    let err = rt.execute("a", &[Arg::Mat(&w)]).unwrap_err();
    assert!(format!("{err}").contains("expected 2 args"));
    // shape
    let small = Matrix::zeros(2, 2);
    let err = rt.execute("a", &[Arg::Mat(&small), Arg::Scalar(1.0)]).unwrap_err();
    assert!(format!("{err}").contains("expects"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scheduler_survives_panicking_free_errors_under_load() {
    // stress: many jobs, several of which fail, across queue pressure
    for cap in [1usize, 2, 64] {
        let cfg = SchedulerConfig { workers: 4, queue_cap: cap };
        let res: Result<Vec<usize>, String> = run_jobs(cfg, (0..500).collect(), |_, j| {
            if j % 97 == 13 {
                Err(format!("fail {j}"))
            } else {
                Ok(j)
            }
        });
        let err = res.unwrap_err();
        assert!(err.starts_with("fail"), "cap={cap}: {err}");
    }
}

#[test]
fn scheduler_many_workers_few_jobs() {
    let cfg = SchedulerConfig { workers: 32, queue_cap: 1 };
    let out: Vec<usize> = run_jobs(cfg, vec![7, 8], |i, j| Ok::<_, ()>(i + j)).unwrap();
    assert_eq!(out, vec![7, 9]);
}

#[test]
fn model_file_corruption_detected() {
    use gpfq::nn::serialize::{load_file, save_file, AlphabetHints};
    let dir = tempdir("model_corrupt");
    let net = gpfq::nn::mnist_mlp(1, 12, &[6], 3);
    let path = dir.join("m.gpfq");
    save_file(&net, &AlphabetHints::new(), &path).unwrap();
    // flip bytes in the header region
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[0] = b'X';
    std::fs::write(&path, &bytes).unwrap();
    assert!(load_file(&path).is_err());
    // truncate mid-layer
    save_file(&net, &AlphabetHints::new(), &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() * 2 / 3]).unwrap();
    assert!(load_file(&path).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
