#!/usr/bin/env python3
"""Mirror runner for `gpfq lint` — the repo-invariant static analysis pass.

The canonical implementation lives in ``rust/src/analysis/`` and runs as
``gpfq lint``; this file is its faithful Python mirror so the gates run in
containers without a Rust toolchain (the repo's standing situation — see
ROADMAP.md).  Both runners share rule names, scopes, the allowlist format
(``rust/lints.allow``), the oracle manifest format (``rust/oracles.lock``)
and the fixture corpus (``rust/tests/lint_fixtures/``); any semantic
divergence between the two is a bug.

Rules (see docs/LINTS.md for rationale):

* ``oracle-freeze``       — SHA-256 manifest over the frozen reference items
* ``panic-path``          — no unwrap/expect/panic!/slice-index on the
                            untrusted-input surfaces (serve::http,
                            nn::serialize)
* ``lock-discipline``     — no nested ``.lock()`` on one line, no I/O under a
                            live guard, no condvar wait outside a predicate
                            loop (scheduler + serve)
* ``float-determinism``   — no new float reductions / ``+=`` accumulator
                            loops outside the frozen kernel files
* ``zero-dep``            — ``[dependencies]`` stays empty; no ``unsafe``

Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""

import argparse
import hashlib
import json
import os
import re
import sys

# --------------------------------------------------------------------------
# shared rule specification (keep bit-identical to rust/src/analysis/)
# --------------------------------------------------------------------------

ALLOWLIST_PATH = "rust/lints.allow"
MANIFEST_PATH = "rust/oracles.lock"
FIXTURES_DIR = "rust/tests/lint_fixtures"

# (file, item) pairs frozen by the oracle-freeze rule; "*" = the whole file.
ORACLE_ITEMS = [
    ("rust/src/coordinator/reference.rs", "*"),
    ("rust/src/nn/kernels.rs", "axpy_lanes"),
    ("rust/src/nn/kernels.rs", "axpy_lanes_i64"),
    ("rust/src/nn/matrix.rs", "axpy"),
    ("rust/src/nn/matrix.rs", "matmul_naive"),
    ("rust/src/nn/matrix.rs", "matmul_tn_naive"),
    ("rust/src/nn/network.rs", "forward_unfused"),
]

# untrusted-input surfaces: requests off the wire, model files off disk;
# plus the obs layer, which must never take a serving or sweep path down
PANIC_PATH_FILES = [
    "rust/src/nn/serialize.rs",
    "rust/src/obs/clock.rs",
    "rust/src/obs/metrics.rs",
    "rust/src/obs/mod.rs",
    "rust/src/obs/span.rs",
    "rust/src/obs/trace.rs",
    "rust/src/serve/http.rs",
]

# files holding locks near I/O / condvars
LOCK_FILES_PREFIXES = [
    "rust/src/coordinator/dist.rs",
    "rust/src/coordinator/scheduler.rs",
    "rust/src/serve/",
]

# the frozen summation trees live here; float reductions are legal inside
FLOAT_EXEMPT_FILES = [
    "rust/src/nn/kernels.rs",
    "rust/src/nn/matrix.rs",
]

# rules whose findings may be allowlisted (oracle-freeze and zero-dep are
# absolute: fixing them means regenerating the manifest / removing the dep)
ALLOWLISTABLE = {"panic-path", "lock-discipline", "float-determinism"}

IO_MARKERS = [
    ".write_all(",
    ".write_fmt(",
    ".flush(",
    ".read(",
    ".read_exact(",
    ".read_to_end(",
    ".read_to_string(",
    "TcpStream::connect",
    "File::open",
    "File::create",
    "std::fs::",
]

WAIT_LOOP_WINDOW = 30  # lines searched upward for the predicate loop
ACC_WINDOW = 40  # lines a float accumulator binding is tracked for `+=`


class Finding:
    def __init__(self, rule, path, line, message, excerpt):
        self.rule = rule
        self.path = path
        self.line = line
        self.message = message
        self.excerpt = excerpt
        self.allowed_by = None

    def as_dict(self):
        d = {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "excerpt": self.excerpt,
        }
        if self.allowed_by is not None:
            d["allowed_by"] = self.allowed_by
        return d


# --------------------------------------------------------------------------
# source model: comment/string stripping, test regions, brace depth
# --------------------------------------------------------------------------


def strip_source(text):
    """Blank out comment bodies and string/char-literal contents, keeping the
    delimiters and every line break, so token scans and brace counting see
    only code.  Handles nested block comments, escapes, raw strings and
    lifetimes the way rustc tokenizes them (closely enough for this repo)."""
    out = []
    i, n = 0, len(text)
    mode = "code"  # code | line | block | str | raw | char
    block_depth = 0
    raw_hashes = 0
    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if mode == "code":
            if c == "/" and nxt == "/":
                mode = "line"
                out.append("  ")
                i += 2
                continue
            if c == "/" and nxt == "*":
                mode = "block"
                block_depth = 1
                out.append("  ")
                i += 2
                continue
            if c == '"':
                mode = "str"
                out.append('"')
                i += 1
                continue
            if (c == "r" or (c == "b" and nxt == "r")) and re.match(
                r'b?r#*"', text[i : i + 8]
            ):
                m = re.match(r'(b?r)(#*)"', text[i : i + 8])
                raw_hashes = len(m.group(2))
                out.append(m.group(0))
                i += len(m.group(0))
                mode = "raw"
                continue
            if c == "'":
                # char literal vs lifetime: a quote closing within 2 chars
                # (or an escape) is a literal, otherwise it's 'lifetime
                if nxt == "\\":
                    j = i + 2
                    while j < n and text[j] != "'":
                        j += 1
                    out.append("'" + " " * (j - i - 1) + "'")
                    i = j + 1
                    continue
                if i + 2 < n and text[i + 2] == "'":
                    out.append("' '")
                    i += 3
                    continue
                out.append(c)
                i += 1
                continue
            out.append(c)
            i += 1
        elif mode == "line":
            if c == "\n":
                mode = "code"
                out.append(c)
            else:
                out.append(" ")
            i += 1
        elif mode == "block":
            if c == "/" and nxt == "*":
                block_depth += 1
                out.append("  ")
                i += 2
            elif c == "*" and nxt == "/":
                block_depth -= 1
                out.append("  ")
                i += 2
                if block_depth == 0:
                    mode = "code"
            else:
                out.append(c if c == "\n" else " ")
                i += 1
        elif mode == "str":
            if c == "\\":
                out.append("  " if nxt != "\n" else " \n")
                i += 2
            elif c == '"':
                mode = "code"
                out.append('"')
                i += 1
            else:
                out.append(c if c == "\n" else " ")
                i += 1
        elif mode == "raw":
            closer = '"' + "#" * raw_hashes
            if text.startswith(closer, i):
                out.append(closer)
                i += len(closer)
                mode = "code"
            else:
                out.append(c if c == "\n" else " ")
                i += 1
        elif mode == "char":  # pragma: no cover - folded into "code" above
            i += 1
    return "".join(out)


class SourceFile:
    """One scanned file: raw lines, code-only lines, per-line test-region
    flags and the brace depth at the start of each line."""

    def __init__(self, path, text):
        self.path = path
        self.raw_lines = text.split("\n")
        stripped = strip_source(text)
        self.code_lines = stripped.split("\n")
        n = len(self.code_lines)
        self.depth_before = [0] * n
        self.is_test = [False] * n
        depth = 0
        test_until_depth = None
        pending_test = False
        for i, code in enumerate(self.code_lines):
            self.depth_before[i] = depth
            if test_until_depth is None and re.search(r"#\[cfg\(test\)\]", code):
                pending_test = True
            if pending_test:
                self.is_test[i] = True
            opens = code.count("{")
            closes = code.count("}")
            if pending_test and opens > 0:
                test_until_depth = depth
                pending_test = False
            depth += opens - closes
            if test_until_depth is not None:
                self.is_test[i] = True
                if depth <= test_until_depth:
                    test_until_depth = None

    def code_line(self, i):
        return self.code_lines[i]

    def raw_line(self, i):
        return self.raw_lines[i] if i < len(self.raw_lines) else ""


def load_source(root, rel):
    path = os.path.join(root, rel)
    with open(path, encoding="utf-8") as f:
        return SourceFile(rel, f.read())


def rust_sources(root):
    """All first-party Rust sources under rust/src (the lint scan set)."""
    out = []
    base = os.path.join(root, "rust", "src")
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames.sort()
        for fn in sorted(filenames):
            if fn.endswith(".rs"):
                rel = os.path.relpath(os.path.join(dirpath, fn), root)
                out.append(rel.replace(os.sep, "/"))
    return out


def unsafe_scan_set(root):
    """rust/src plus tests/benches/examples — everywhere `unsafe` is banned.
    The fixture corpus is excluded: it deliberately contains violations."""
    rels = list(rust_sources(root))
    for extra in ("rust/tests", "benches", "examples"):
        base = os.path.join(root, extra)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames.sort()
            for fn in sorted(filenames):
                if fn.endswith(".rs"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    rel = rel.replace(os.sep, "/")
                    if not rel.startswith(FIXTURES_DIR + "/"):
                        rels.append(rel)
    return rels


# --------------------------------------------------------------------------
# oracle-freeze
# --------------------------------------------------------------------------


def normalize_span(lines):
    return "\n".join(ln.rstrip() for ln in lines) + "\n"


def extract_item(src, item):
    """The raw text of `fn <item>` (signature through the matching close
    brace), or of the whole file for "*".  Returns None if absent."""
    if item == "*":
        return normalize_span(src.raw_lines)
    sig_re = re.compile(r"\bfn\s+" + re.escape(item) + r"\s*[(<]")
    for i, code in enumerate(src.code_lines):
        if src.is_test[i] or not sig_re.search(code):
            continue
        depth = 0
        opened = False
        for j in range(i, len(src.code_lines)):
            for ch in src.code_lines[j]:
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
            if opened and depth <= 0:
                return normalize_span(src.raw_lines[i : j + 1])
        return None
    return None


def item_hash(text):
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


def compute_manifest(root):
    """name → sha256 for every frozen item present under `root`."""
    entries = {}
    for rel, item in ORACLE_ITEMS:
        if not os.path.isfile(os.path.join(root, rel)):
            continue
        src = load_source(root, rel)
        text = extract_item(src, item)
        if text is not None:
            entries[f"{rel}::{item}"] = item_hash(text)
    return entries


MANIFEST_HEADER = """\
# gpfq frozen-oracle manifest (lint rule: oracle-freeze).
#
# Each line pins the SHA-256 of one frozen reference item: the naive
# matmul oracles, the scalar axpy bodies, the unfused forward pass and
# the whole pre-refactor reference module.  Any edit to those sources
# fails `gpfq lint` / `python/tools/lint.py` until this manifest is
# regenerated IN THE SAME CHANGE with:
#
#   python3 python/tools/lint.py --fix-manifest    (or: gpfq lint --fix-manifest)
#
# which makes oracle drift loud and reviewable instead of silent.
"""


def parse_manifest(path):
    entries = {}
    with open(path, encoding="utf-8") as f:
        for ln in f:
            ln = ln.strip()
            if not ln or ln.startswith("#"):
                continue
            parts = ln.split()
            if len(parts) != 2 or not parts[1].startswith("sha256="):
                raise ValueError(f"malformed manifest line: {ln!r}")
            entries[parts[0]] = parts[1][len("sha256=") :]
    return entries


def write_manifest(path, entries):
    lines = [MANIFEST_HEADER]
    for name in sorted(entries):
        lines.append(f"{name} sha256={entries[name]}\n")
    with open(path, "w", encoding="utf-8") as f:
        f.write("".join(lines))


def rule_oracle_freeze(root, findings):
    current = compute_manifest(root)
    mpath = os.path.join(root, MANIFEST_PATH)
    if not os.path.isfile(mpath):
        if current:
            findings.append(
                Finding(
                    "oracle-freeze",
                    MANIFEST_PATH,
                    0,
                    "manifest missing; run --fix-manifest to freeze the oracles",
                    "",
                )
            )
        return
    try:
        pinned = parse_manifest(mpath)
    except ValueError as e:
        findings.append(Finding("oracle-freeze", MANIFEST_PATH, 0, str(e), ""))
        return
    for name in sorted(set(pinned) | set(current)):
        if name not in current:
            findings.append(
                Finding(
                    "oracle-freeze",
                    MANIFEST_PATH,
                    0,
                    f"pinned oracle item {name} no longer exists in the sources",
                    "",
                )
            )
        elif name not in pinned:
            findings.append(
                Finding(
                    "oracle-freeze",
                    MANIFEST_PATH,
                    0,
                    f"oracle item {name} is not pinned; run --fix-manifest",
                    "",
                )
            )
        elif pinned[name] != current[name]:
            findings.append(
                Finding(
                    "oracle-freeze",
                    name.split("::")[0],
                    0,
                    f"frozen oracle {name} drifted from its pinned hash "
                    f"(pinned {pinned[name][:12]}…, source {current[name][:12]}…); "
                    "if the change is intentional, regenerate with --fix-manifest",
                    "",
                )
            )


# --------------------------------------------------------------------------
# panic-path
# --------------------------------------------------------------------------

PANIC_TOKENS = [
    (".unwrap()", "unwrap() on an untrusted-input surface"),
    (".expect(", "expect() on an untrusted-input surface"),
    ("panic!(", "panic!() on an untrusted-input surface"),
    ("unreachable!(", "unreachable!() on an untrusted-input surface"),
    ("todo!(", "todo!() on an untrusted-input surface"),
    ("unimplemented!(", "unimplemented!() on an untrusted-input surface"),
]

INDEX_RE = re.compile(r"[A-Za-z0-9_\)\]]\[")


def rule_panic_path(root, findings):
    for rel in PANIC_PATH_FILES:
        if not os.path.isfile(os.path.join(root, rel)):
            continue
        src = load_source(root, rel)
        for i, code in enumerate(src.code_lines):
            if src.is_test[i]:
                continue
            for token, msg in PANIC_TOKENS:
                if token in code:
                    findings.append(
                        Finding("panic-path", rel, i + 1, msg, src.raw_line(i).strip())
                    )
            if code.lstrip().startswith("#"):
                continue  # attributes like #[derive(..)] index nothing
            if INDEX_RE.search(code):
                findings.append(
                    Finding(
                        "panic-path",
                        rel,
                        i + 1,
                        "slice/array index (can panic) on an untrusted-input surface",
                        src.raw_line(i).strip(),
                    )
                )


# --------------------------------------------------------------------------
# lock-discipline
# --------------------------------------------------------------------------

GUARD_RE = re.compile(r"\blet\s+(?:mut\s+)?(\w+)\s*=.*\.lock\(")
WAIT_RE = re.compile(r"\.wait(_timeout)?\(")
LOOP_RE = re.compile(r"\b(loop|while)\b")


def rule_lock_discipline(root, findings):
    for rel in rust_sources(root):
        if not any(
            rel == p or (p.endswith("/") and rel.startswith(p))
            for p in LOCK_FILES_PREFIXES
        ):
            continue
        src = load_source(root, rel)
        live_guards = []  # (name, depth_at_binding, line)
        for i, code in enumerate(src.code_lines):
            if src.is_test[i]:
                continue
            depth = src.depth_before[i]
            live_guards = [g for g in live_guards if depth >= g[1]]
            if code.count(".lock(") >= 2:
                findings.append(
                    Finding(
                        "lock-discipline",
                        rel,
                        i + 1,
                        "nested .lock() acquisitions in one expression",
                        src.raw_line(i).strip(),
                    )
                )
            if WAIT_RE.search(code):
                lo = max(0, i - WAIT_LOOP_WINDOW)
                window = src.code_lines[lo:i]
                if not any(LOOP_RE.search(w) for w in window):
                    findings.append(
                        Finding(
                            "lock-discipline",
                            rel,
                            i + 1,
                            "condvar wait outside a predicate loop "
                            "(spurious wakeups break the invariant)",
                            src.raw_line(i).strip(),
                        )
                    )
            for name, _, bind_line in live_guards:
                if re.search(r"\bdrop\(\s*" + re.escape(name) + r"\s*\)", code):
                    live_guards = [g for g in live_guards if g[0] != name]
                    break
            if any(m in code for m in IO_MARKERS) and live_guards:
                g = live_guards[-1]
                findings.append(
                    Finding(
                        "lock-discipline",
                        rel,
                        i + 1,
                        f"I/O while lock guard `{g[0]}` (bound line {g[2]}) is live",
                        src.raw_line(i).strip(),
                    )
                )
            m = GUARD_RE.search(code)
            if m:
                live_guards.append((m.group(1), depth, i + 1))


# --------------------------------------------------------------------------
# float-determinism
# --------------------------------------------------------------------------

REDUCE_RE = re.compile(
    r"\.sum::<f(32|64)>\(\)|\.fold\(0(?:\.0(?:f32|f64)?|f32|f64)\s*,"
)
ACC_BIND_RE = re.compile(r"\blet\s+mut\s+(\w+)\s*=\s*0(\.0)?(f32|f64)?\s*;")


def rule_float_determinism(root, findings):
    for rel in rust_sources(root):
        if rel in FLOAT_EXEMPT_FILES:
            continue
        src = load_source(root, rel)
        acc = []  # (name, depth, bind_line)
        for i, code in enumerate(src.code_lines):
            if src.is_test[i]:
                continue
            depth = src.depth_before[i]
            acc = [a for a in acc if depth >= a[1] and i - a[2] <= ACC_WINDOW]
            if REDUCE_RE.search(code):
                findings.append(
                    Finding(
                        "float-determinism",
                        rel,
                        i + 1,
                        "float reduction outside the frozen kernel files "
                        "(summation order must stay reviewable)",
                        src.raw_line(i).strip(),
                    )
                )
            for name, _, bind_line in acc:
                if re.search(r"\b" + re.escape(name) + r"\s*[+-]=", code):
                    findings.append(
                        Finding(
                            "float-determinism",
                            rel,
                            i + 1,
                            f"float `+=` accumulator loop (`{name}` bound line "
                            f"{bind_line}) outside the frozen kernel files",
                            src.raw_line(i).strip(),
                        )
                    )
                    acc = [a for a in acc if a[0] != name]
                    break
            m = ACC_BIND_RE.search(code)
            if m and (m.group(2) or m.group(3)):  # 0.0 / 0f32 / 0f64, not `0`
                acc.append((m.group(1), depth, i))


# --------------------------------------------------------------------------
# zero-dep
# --------------------------------------------------------------------------

DEP_SECTIONS = (
    "dependencies",
    "dev-dependencies",
    "build-dependencies",
    "workspace.dependencies",
)


def rule_zero_dep(root, findings):
    for rel in ("Cargo.toml", "rust/Cargo.toml"):
        path = os.path.join(root, rel)
        if not os.path.isfile(path):
            continue
        section = None
        with open(path, encoding="utf-8") as f:
            for i, ln in enumerate(f, 1):
                s = ln.split("#", 1)[0].strip()
                if not s:
                    continue
                if s.startswith("["):
                    section = s.strip("[]").strip()
                    continue
                if section in DEP_SECTIONS and "=" in s:
                    findings.append(
                        Finding(
                            "zero-dep",
                            rel,
                            i,
                            f"external dependency in [{section}] — the crate is "
                            "zero-dep by contract (vendor a stand-in under src/)",
                            ln.strip(),
                        )
                    )
    unsafe_re = re.compile(r"\bunsafe\b")
    for rel in unsafe_scan_set(root):
        src = load_source(root, rel)
        for i, code in enumerate(src.code_lines):
            if unsafe_re.search(code):
                findings.append(
                    Finding(
                        "zero-dep",
                        rel,
                        i + 1,
                        "`unsafe` is banned crate-wide (no unsafe has ever "
                        "been needed; Miri runs only advisory)",
                        src.raw_line(i).strip(),
                    )
                )


# --------------------------------------------------------------------------
# allowlist
# --------------------------------------------------------------------------


class AllowEntry:
    def __init__(self, rule, path, needle, justification, line):
        self.rule = rule
        self.path = path
        self.needle = needle
        self.justification = justification
        self.line = line
        self.used = False


def parse_allowlist(path, findings):
    entries = []
    if not os.path.isfile(path):
        return entries
    with open(path, encoding="utf-8") as f:
        for i, ln in enumerate(f, 1):
            s = ln.strip()
            if not s or s.startswith("#"):
                continue
            parts = [p.strip() for p in s.split("|", 3)]
            if len(parts) != 4 or not all(parts[:3]):
                findings.append(
                    Finding(
                        "allowlist",
                        ALLOWLIST_PATH,
                        i,
                        "malformed entry: want `rule | path | needle | justification`",
                        s,
                    )
                )
                continue
            rule, fpath, needle, just = parts
            if rule not in ALLOWLISTABLE:
                findings.append(
                    Finding(
                        "allowlist",
                        ALLOWLIST_PATH,
                        i,
                        f"rule {rule!r} cannot be allowlisted",
                        s,
                    )
                )
                continue
            if not just:
                findings.append(
                    Finding(
                        "allowlist",
                        ALLOWLIST_PATH,
                        i,
                        "entry has no justification — every exception must say why",
                        s,
                    )
                )
                continue
            entries.append(AllowEntry(rule, fpath, needle, just, i))
    return entries


def apply_allowlist(findings, entries):
    kept = []
    for f in findings:
        matched = None
        for e in entries:
            if e.rule == f.rule and e.path == f.path and e.needle in f.excerpt:
                matched = e
                break
        if matched is None:
            kept.append(f)
        else:
            matched.used = True
            f.allowed_by = matched.line
    return kept


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------


def run_lint(root):
    """Run every rule rooted at `root`.  Returns (active, allowed, stale)
    where `active` are unallowlisted findings (nonzero exit), `allowed` the
    suppressed ones and `stale` the unused allowlist entries."""
    findings = []
    rule_oracle_freeze(root, findings)
    rule_panic_path(root, findings)
    rule_lock_discipline(root, findings)
    rule_float_determinism(root, findings)
    rule_zero_dep(root, findings)
    config_findings = []
    entries = parse_allowlist(os.path.join(root, ALLOWLIST_PATH), config_findings)
    allowlistable = [f for f in findings if f.rule in ALLOWLISTABLE]
    absolute = [f for f in findings if f.rule not in ALLOWLISTABLE]
    active = apply_allowlist(allowlistable, entries)
    allowed = [f for f in allowlistable if f.allowed_by is not None]
    stale = [e for e in entries if not e.used]
    return absolute + config_findings + active, allowed, stale


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="gpfq lint (Python mirror of rust/src/analysis)"
    )
    ap.add_argument("--root", default=None, help="repo root (default: autodetect)")
    ap.add_argument("--json", action="store_true", help="machine-readable report")
    ap.add_argument(
        "--fix-manifest",
        action="store_true",
        help="regenerate rust/oracles.lock from the current sources",
    )
    args = ap.parse_args(argv)
    root = args.root or os.path.abspath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
    )
    if not os.path.isdir(os.path.join(root, "rust", "src")):
        print(f"error: {root} does not look like the repo root", file=sys.stderr)
        return 2

    if args.fix_manifest:
        entries = compute_manifest(root)
        write_manifest(os.path.join(root, MANIFEST_PATH), entries)
        print(f"wrote {MANIFEST_PATH} ({len(entries)} frozen items)")
        return 0

    active, allowed, stale = run_lint(root)
    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.as_dict() for f in active],
                    "allowed": [f.as_dict() for f in allowed],
                    "stale_allowlist_lines": [e.line for e in stale],
                    "ok": not active,
                },
                indent=2,
            )
        )
    else:
        for f in active:
            loc = f"{f.path}:{f.line}" if f.line else f.path
            print(f"{loc}: [{f.rule}] {f.message}")
            if f.excerpt:
                print(f"    {f.excerpt}")
        for e in stale:
            print(
                f"note: {ALLOWLIST_PATH}:{e.line}: allowlist entry matched nothing "
                f"(stale?): {e.rule} | {e.path} | {e.needle}"
            )
        print(
            f"lint: {len(active)} finding(s), {len(allowed)} allowlisted, "
            f"{len(stale)} stale allowlist entr(y/ies)"
        )
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
