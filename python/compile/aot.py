"""AOT compiler driver: lower every L1/L2 graph to HLO *text* artifacts.

Interchange format is HLO text, NOT ``lowered.compile()`` / ``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the Rust side (``HloModuleProto::from_text_file``)
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never runs on the Rust
request path.  Emits ``artifacts/*.hlo.txt`` plus ``artifacts/manifest.json``
describing each executable's parameter/output shapes, parsed by
``rust/src/runtime/artifact.rs``.

Usage:  python -m compile.aot --out ../artifacts [--quick] [--list]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# default network shapes (must match configs/*.toml on the Rust side)
# ---------------------------------------------------------------------------

MQ = 512      # rows of the quantization data matrix per artifact
BLOCK_B = 64  # neuron-block width (Rust pads the last block with zero neurons)

# paper Section 6.1 MLP: 784-500-300-10 (MNIST-like)
MNIST_DIMS = (784, 500, 300, 10)
# end-to-end driver net (trained from Rust through the train_step artifact)
E2E_DIMS = (784, 128, 64, 10)
E2E_BATCH = 128
EVAL_BATCH = MQ


def f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Spec:
    """One artifact: a jitted function plus its example input shapes."""

    def __init__(self, name, kind, fn, params, outputs, meta=None, quick=False):
        self.name = name
        self.kind = kind
        self.fn = fn
        self.params = params      # list[(pname, ShapeDtypeStruct)]
        self.outputs = outputs    # list[ShapeDtypeStruct]
        self.meta = meta or {}
        self.quick = quick        # part of the --quick subset

    def manifest_entry(self):
        def desc(s):
            return {"shape": list(s.shape), "dtype": "f32"}

        return {
            "name": self.name,
            "file": f"{self.name}.hlo.txt",
            "kind": self.kind,
            "params": [dict(name=n, **desc(s)) for n, s in self.params],
            "outputs": [desc(s) for s in self.outputs],
            "meta": self.meta,
        }


def gpfq_spec(m, n, b, M, quick=False):
    fn = functools.partial(model.gpfq_block, M=M, block_b=b)
    return Spec(
        name=f"gpfq_m{m}_n{n}_b{b}_M{M}",
        kind="gpfq",
        fn=fn,
        params=[("Y", f32(m, n)), ("Yt", f32(m, n)), ("W", f32(n, b)), ("alpha", f32())],
        outputs=[f32(n, b)],
        meta={"m": m, "n": n, "b": b, "M": M},
        quick=quick,
    )


def msq_spec(n, b, M, quick=False):
    fn = functools.partial(model.msq_block, M=M, block_b=b)
    return Spec(
        name=f"msq_n{n}_b{b}_M{M}",
        kind="msq",
        fn=fn,
        params=[("W", f32(n, b)), ("alpha", f32())],
        outputs=[f32(n, b)],
        meta={"n": n, "b": b, "M": M},
        quick=quick,
    )


def dense_spec(m, n, k, act, quick=False):
    fn = functools.partial(model.dense_fwd, act=act)
    return Spec(
        name=f"dense_m{m}_n{n}_k{k}_{act}",
        kind="dense",
        fn=fn,
        params=[("Y", f32(m, n)), ("W", f32(n, k)), ("b", f32(k))],
        outputs=[f32(m, k)],
        meta={"m": m, "n": n, "k": k, "act": act},
        quick=quick,
    )


def mlp_spec(batch, dims, quick=False):
    fn = functools.partial(model.mlp_fwd, dims=dims)
    params = [("x", f32(batch, dims[0]))]
    for i in range(len(dims) - 1):
        params.append((f"W{i + 1}", f32(dims[i], dims[i + 1])))
        params.append((f"b{i + 1}", f32(dims[i + 1])))
    # mlp_fwd takes x first; reorder to (x, *wb) at call time below
    name = "mlp_fwd_b%d_%s" % (batch, "x".join(map(str, dims)))
    return Spec(
        name=name,
        kind="mlp_fwd",
        fn=fn,
        params=params,
        outputs=[f32(batch, dims[-1])],
        meta={"batch": batch, "dims": list(dims)},
        quick=quick,
    )


def train_spec(batch, dims, quick=False):
    fn = functools.partial(model.train_step, dims=dims)
    params = []
    for i in range(len(dims) - 1):
        params.append((f"W{i + 1}", f32(dims[i], dims[i + 1])))
        params.append((f"b{i + 1}", f32(dims[i + 1])))
    params += [("x", f32(batch, dims[0])), ("y_onehot", f32(batch, dims[-1])), ("lr", f32())]
    outputs = [s for _, s in params[: 2 * (len(dims) - 1)]] + [f32()]
    name = "train_step_b%d_%s" % (batch, "x".join(map(str, dims)))
    return Spec(
        name=name,
        kind="train_step",
        fn=fn,
        params=params,
        outputs=outputs,
        meta={"batch": batch, "dims": list(dims)},
        quick=quick,
    )


def default_specs():
    specs = []
    # --- GPFQ neuron-block quantizers -------------------------------------
    # MNIST MLP layer input widths x {ternary, 4-bit}; e2e net widths ternary.
    for n in MNIST_DIMS[:-1]:
        for M in (3, 16):
            specs.append(gpfq_spec(MQ, n, BLOCK_B, M, quick=(n == 300 and M == 3)))
    for n in E2E_DIMS[1:-1]:
        specs.append(gpfq_spec(MQ, n, BLOCK_B, 3))
    # --- MSQ parity artifacts ----------------------------------------------
    specs.append(msq_spec(784, BLOCK_B, 3, quick=True))
    specs.append(msq_spec(500, BLOCK_B, 16))
    # --- layer-by-layer forward (activation streaming in the pipeline) ----
    mnist = MNIST_DIMS
    for i in range(len(mnist) - 1):
        act = "relu" if i < len(mnist) - 2 else "none"
        specs.append(dense_spec(MQ, mnist[i], mnist[i + 1], act, quick=(i == len(mnist) - 2)))
    for i in range(len(E2E_DIMS) - 1):
        act = "relu" if i < len(E2E_DIMS) - 2 else "none"
        specs.append(dense_spec(MQ, E2E_DIMS[i], E2E_DIMS[i + 1], act))
    # --- fused eval + train step for the e2e driver -----------------------
    specs.append(mlp_spec(EVAL_BATCH, E2E_DIMS, quick=True))
    specs.append(mlp_spec(EVAL_BATCH, MNIST_DIMS))
    specs.append(train_spec(E2E_BATCH, E2E_DIMS, quick=True))
    names = [s.name for s in specs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return specs


def emit(spec: Spec, out_dir: str) -> str:
    shapes = [s for _, s in spec.params]
    lowered = jax.jit(spec.fn).lower(*shapes)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="emit only the quick subset")
    ap.add_argument("--only", default=None, help="emit only artifacts whose name contains this substring")
    ap.add_argument("--list", action="store_true", help="list artifact names and exit")
    args = ap.parse_args(argv)

    specs = default_specs()
    if args.quick:
        specs = [s for s in specs if s.quick]
    if args.only:
        specs = [s for s in specs if args.only in s.name]
    if args.list:
        for s in specs:
            print(s.name)
        return 0

    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "block_b": BLOCK_B, "mq": MQ, "artifacts": []}
    for i, spec in enumerate(specs):
        path = emit(spec, args.out)
        size = os.path.getsize(path)
        manifest["artifacts"].append(spec.manifest_entry())
        print(f"[{i + 1}/{len(specs)}] {spec.name}  ({size // 1024} KiB)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(specs)} artifacts + manifest.json to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
