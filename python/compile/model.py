"""L2: JAX compute graphs lowered to AOT artifacts for the Rust runtime.

Three families of graphs, all build-time only:

  * quantization ops -- thin wrappers over the L1 Pallas kernels
    (``gpfq_block`` / ``msq_block``) with the exact signatures the Rust
    coordinator executes per neuron block;
  * inference ops -- ``dense_fwd`` (one layer) and ``mlp_fwd`` (fused net),
    used by the coordinator to stream the analog/quantized activation pairs
    through the network during layer-sequential quantization, and by the
    evaluation path;
  * training op -- ``train_step`` (fwd + bwd via jax.grad + SGD update),
    the substrate that produces the *pre-trained* float networks the paper
    assumes as input.  The Rust e2e driver loops this executable.

Biases are handled the way the paper prescribes (Section 4): at
quantization time the Rust side augments activations with a constant-1
column and folds b into W, so the graphs here carry explicit biases only in
the training/inference paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.gpfq import gpfq_quantize
from .kernels.msq import msq_quantize

ACTIVATIONS = ("relu", "none", "softmax")


def activate(z: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    if act == "softmax":
        return jax.nn.softmax(z, axis=-1)
    raise ValueError(f"unknown activation {act!r} (expected one of {ACTIVATIONS})")


# ---------------------------------------------------------------------------
# quantization graphs (wrap the L1 kernels)
# ---------------------------------------------------------------------------

def gpfq_block(Y, Yt, W, alpha, *, M: int, block_b: int):
    """Quantize one neuron block with GPFQ.  Returns a 1-tuple (Q,).

    Lowered per shape as artifact ``gpfq_m{m}_n{N}_b{B}_M{M}``.
    """
    return (gpfq_quantize(Y, Yt, W, alpha, M=M, block_b=block_b),)


def msq_block(W, alpha, *, M: int, block_b: int):
    """Quantize one neuron block with MSQ.  Returns a 1-tuple (Q,)."""
    return (msq_quantize(W, alpha, M=M, block_b=block_b),)


# ---------------------------------------------------------------------------
# inference graphs
# ---------------------------------------------------------------------------

def dense_fwd(Y, W, b, *, act: str):
    """One affine layer + activation: act(Y @ W + b).  1-tuple output."""
    return (activate(Y @ W + b[None, :], act),)


def mlp_fwd(x, *params, dims, act="relu"):
    """Fused forward pass of an MLP given interleaved (W, b) params.

    ``dims`` = (d0, d1, ..., dL); hidden layers use ``act``, output layer is
    linear (logits -- softmax/argmax happen on the Rust side).
    """
    n_layers = len(dims) - 1
    assert len(params) == 2 * n_layers, (len(params), dims)
    h = x
    for i in range(n_layers):
        W, b = params[2 * i], params[2 * i + 1]
        h = h @ W + b[None, :]
        if i + 1 < n_layers:
            h = activate(h, act)
    return (h,)


# ---------------------------------------------------------------------------
# training graph
# ---------------------------------------------------------------------------

def _ce_loss(params, x, y_onehot, *, dims):
    (logits,) = mlp_fwd(x, *params, dims=dims)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step(*args, dims):
    """One SGD step on softmax cross-entropy.

    args = (W1, b1, ..., WL, bL, x, y_onehot, lr); returns
    (W1', b1', ..., WL', bL', loss) flattened as a tuple so the Rust driver
    can round-trip parameters without pytree knowledge.
    """
    n_layers = len(dims) - 1
    params = list(args[: 2 * n_layers])
    x, y_onehot, lr = args[2 * n_layers], args[2 * n_layers + 1], args[2 * n_layers + 2]
    loss, grads = jax.value_and_grad(_ce_loss)(params, x, y_onehot, dims=dims)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


def init_mlp_params(key, dims):
    """He-initialized MLP parameters, interleaved (W, b) like train_step."""
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        params.append(jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32) * scale)
        params.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return params


# ---------------------------------------------------------------------------
# conv-as-im2col (paper Section 6.2): parity oracle for the Rust substrate
# ---------------------------------------------------------------------------

def im2col(X, kh: int, kw: int, stride: int = 1):
    """Extract flattened conv patches: (B, H, W, C) -> (B*OH*OW, kh*kw*C).

    Matches ``rust/src/nn/conv.rs``: patches are row-major over (b, oh, ow)
    and each patch flattens (dy, dx, c) in that order.  The paper quantizes
    conv kernels by treating these patch rows as the data matrix X.
    """
    Bn, H, W, C = X.shape
    oh = (H - kh) // stride + 1
    ow = (W - kw) // stride + 1
    patches = []
    for dy in range(kh):
        for dx in range(kw):
            patches.append(
                jax.lax.slice(
                    X, (0, dy, dx, 0), (Bn, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1, C),
                    (1, stride, stride, 1),
                )
            )
    # each entry: (B, OH, OW, C); stack to (B, OH, OW, kh*kw, C)
    stacked = jnp.stack(patches, axis=3)
    return stacked.reshape(Bn * oh * ow, kh * kw * C)


def conv2d_fwd(X, K, b, *, stride: int = 1, act: str = "relu"):
    """Valid conv via im2col + matmul: K is (kh*kw*Cin, Cout) flattened."""
    Bn, H, W, C = X.shape
    kh = kw = int(round((K.shape[0] // C) ** 0.5))
    assert kh * kw * C == K.shape[0], (K.shape, C)
    oh = (H - kh) // stride + 1
    ow = (W - kw) // stride + 1
    cols = im2col(X, kh, kw, stride)
    out = activate(cols @ K + b[None, :], act)
    return (out.reshape(Bn, oh, ow, K.shape[1]),)
