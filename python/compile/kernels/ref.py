"""Pure-jnp correctness oracles for the GPFQ / MSQ quantizers.

These references are deliberately written in the *definitional* form of the
paper (Lybrand & Saab 2020): the per-step quantization decision is taken by
brute-force ``argmin`` over every character of the alphabet (paper eq. (2) /
(3)) rather than through the concise projection form of Lemma 1.  The Pallas
kernel (``kernels/gpfq.py``) uses the Lemma 1 form, so agreement between the
two is simultaneously a correctness check of the kernel *and* a numerical
verification of Lemma 1.

Everything here is build/test-time only; nothing in this module is ever on
the Rust request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# A zero column carries no information: any alphabet choice leaves the state
# u unchanged.  Both the reference and the kernel resolve the ambiguity the
# same way (fall back to memoryless quantization of the weight itself), which
# also makes zero-padding of the t axis a no-op -- the property the Rust
# coordinator relies on to use bucketed artifact shapes.
DENOM_EPS = 1e-12


def alphabet(M: int, alpha) -> jnp.ndarray:
    """The equispaced alphabet  A = alpha * {-1 + 2j/(M-1) : 0 <= j < M}.

    ``M = 3`` recovers the ternary alphabet ``{-alpha, 0, alpha}`` used for
    the paper's MNIST and ImageNet experiments.
    """
    if M < 2:
        raise ValueError(f"alphabet needs M >= 2 characters, got {M}")
    levels = -1.0 + 2.0 * jnp.arange(M, dtype=jnp.float32) / (M - 1)
    return jnp.asarray(alpha, jnp.float32) * levels


def msq_ref(W: jnp.ndarray, alpha, M: int) -> jnp.ndarray:
    """Memoryless scalar quantization: nearest alphabet character per weight.

    This is the paper's baseline (Rastegari et al.'s sign-quantizer
    generalized to equispaced alphabets).  Brute-force nearest neighbour
    over the alphabet -- shape (M,) broadcast against W.
    """
    A = alphabet(M, alpha)
    dists = jnp.abs(W[..., None] - A)  # (..., M)
    return A[jnp.argmin(dists, axis=-1)]


def gpfq_step_ref(u, y, yt, w, A):
    """One step of paper eq. (3), decided by explicit argmin over A.

    u  : (m, B)  running state per neuron
    y  : (m,)    analog activation column Y_t
    yt : (m,)    quantized-network activation column Y~_t
    w  : (B,)    row t of the weight block
    A  : (M,)    alphabet
    returns (u_next, q) with q : (B,)
    """
    # candidate residuals: u + w_t * Y_t - p * Y~_t for every p in A
    base = u + y[:, None] * w[None, :]  # (m, B)
    cand = base[:, :, None] - yt[:, None, None] * A[None, None, :]  # (m, B, M)
    costs = jnp.sum(cand * cand, axis=0)  # (B, M)
    idx = jnp.argmin(costs, axis=-1)  # (B,)
    q = A[idx]
    denom = jnp.sum(yt * yt)
    # zero column: no information, fall back to MSQ of the weight itself.
    msq = A[jnp.argmin(jnp.abs(w[:, None] - A[None, :]), axis=-1)]
    q = jnp.where(denom > DENOM_EPS, q, msq)
    u_next = base - yt[:, None] * q[None, :]
    return u_next, q


def gpfq_ref(Y: jnp.ndarray, Yt: jnp.ndarray, W: jnp.ndarray, alpha, M: int):
    """Quantize a block of neurons with GPFQ (paper eq. (3)), returning (Q, U).

    Y  : (m, N) analog activations of the previous layer
    Yt : (m, N) activations of the quantized network so far
    W  : (N, B) neuron block (columns are neurons)
    Q  : (N, B) quantized block, U : (m, B) final state (Yw - Y~q per neuron)

    First-layer quantization (paper eq. (2)) is the special case ``Yt = Y``.
    """
    m, N = Y.shape
    assert Yt.shape == (m, N), (Yt.shape, (m, N))
    assert W.shape[0] == N, (W.shape, N)
    A = alphabet(M, alpha)

    def body(u, inp):
        y, yt, w = inp
        u_next, q = gpfq_step_ref(u, y, yt, w, A)
        return u_next, q

    u0 = jnp.zeros((m, W.shape[1]), jnp.float32)
    U, Q = jax.lax.scan(body, u0, (Y.T, Yt.T, W))
    return Q, U


def gpfq_error_ref(Y, Yt, W, alpha, M):
    """Relative quantization error per neuron: ||Yw - Y~q|| / ||Yw||."""
    Q, U = gpfq_ref(Y, Yt, W, alpha, M)
    num = jnp.linalg.norm(U, axis=0)
    den = jnp.linalg.norm(Y @ W, axis=0)
    return num / jnp.maximum(den, DENOM_EPS)


def median_alpha(W: jnp.ndarray, c_alpha: float) -> jnp.ndarray:
    """Paper Section 6 alphabet radius: alpha = C_alpha * median(|W_ij|)."""
    return jnp.asarray(c_alpha, jnp.float32) * jnp.median(jnp.abs(W))
