"""L1: memoryless scalar quantization (MSQ) as a Pallas kernel.

MSQ is the paper's baseline throughout Section 6 (Figure 1, Table 1,
Table 2): each weight is independently snapped to the nearest character of
the alphabet.  Trivially elementwise, so the kernel exists mainly (a) to
give the MSQ baseline the same artifact treatment as GPFQ so that the Rust
coordinator benchmarks apples-to-apples executables, and (b) as the simplest
possible Pallas example in the repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gpfq import nearest_level


def _msq_kernel(w_ref, alpha_ref, q_ref, *, M: int):
    q_ref[...] = nearest_level(w_ref[...], alpha_ref[0, 0], M)


def msq_quantize(W, alpha, *, M: int, block_b: int | None = None):
    """Quantize a weight matrix elementwise: Q_ij = nearest level to W_ij."""
    N, n = W.shape
    if block_b is None:
        block_b = min(n, 64)
    if n % block_b != 0:
        raise ValueError(f"neuron count {n} not divisible by block {block_b}")
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)
    kernel = functools.partial(_msq_kernel, M=M)
    return pl.pallas_call(
        kernel,
        grid=(n // block_b,),
        in_specs=[
            pl.BlockSpec((N, block_b), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((N, block_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((N, n), jnp.float32),
        interpret=True,
    )(W, alpha_arr)
