"""L1: the GPFQ greedy path-following quantizer as a Pallas kernel.

The compute hot-spot of the paper is the per-neuron dynamical system
(eq. (2)/(3)):

    u_0 = 0
    q_t = argmin_{p in A} || u_{t-1} + w_t Y_t - p Y~_t ||_2^2
    u_t = u_{t-1} + w_t Y_t - q_t Y~_t

The kernel uses the concise form of Lemma 1 (generalized to layer >= 2 and
to arbitrary equispaced alphabets):

    q_t = Q_A( <Y~_t, u_{t-1} + w_t Y_t> / ||Y~_t||^2 )

where Q_A is the memoryless nearest-character quantizer over
A = alpha * {-1 + 2j/(M-1)}.  The purely-definitional argmin oracle lives in
``ref.py``; their agreement is checked by pytest and *is* a numerical proof
of Lemma 1.

Parallelization layout (the paper's "parallelizable across neurons"):

  * grid axis 0 = neuron blocks of width B -- each grid program owns an
    independent state matrix U in registers/VMEM and is embarrassingly
    parallel (TPU: B maps to lanes, multiples of 128 in production; we use
    smaller B under interpret mode);
  * the t axis is the sequential path-following order, consumed by a
    ``lax.scan`` inside the kernel.  On a real TPU the Y/Y~ columns would be
    streamed HBM->VMEM in double-buffered (m x T) tiles; see DESIGN.md
    section "Hardware adaptation" for the VMEM budget.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowering produces plain HLO that the Rust
runtime executes unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DENOM_EPS


def nearest_level(z, alpha, M: int):
    """Memoryless quantizer Q_A(z): nearest character of the equispaced
    alphabet A = alpha * {-1 + 2j/(M-1)}, computed in closed form.

    Clamp to [-alpha, alpha], snap to the nearest of the M levels.  Matches
    argmin_{p in A} |z - p| up to ties (measure-zero for float data; the
    round-half-to-even convention of jnp.round decides ties).
    """
    half_step = alpha / (M - 1)  # half the spacing 2*alpha/(M-1)
    # index of nearest level: j = round((z + alpha) / (2*alpha/(M-1)))
    j = jnp.round((z + alpha) / jnp.maximum(2.0 * half_step, DENOM_EPS))
    j = jnp.clip(j, 0.0, float(M - 1))
    return -alpha + 2.0 * half_step * j


def _gpfq_kernel(y_ref, yt_ref, w_ref, alpha_ref, q_ref, *, M: int):
    """Pallas kernel body: quantize one B-wide neuron block.

    y_ref     : (m, N)  analog activations        (VMEM tile)
    yt_ref    : (m, N)  quantized-net activations (VMEM tile)
    w_ref     : (N, B)  neuron block
    alpha_ref : (1, 1)  alphabet radius (runtime input so one artifact
                        serves the whole C_alpha cross-validation sweep)
    q_ref     : (N, B)  output block
    """
    Y = y_ref[...]
    Yt = yt_ref[...]
    W = w_ref[...]
    alpha = alpha_ref[0, 0]
    m, _ = Y.shape
    B = W.shape[1]

    def step(u, inp):
        y, yt, w = inp  # (m,), (m,), (B,)
        denom = jnp.sum(yt * yt)
        # Lemma 1 (general-layer form): projection of the walked state onto
        # the quantized direction.
        proj = (yt @ u + (yt @ y) * w) / jnp.maximum(denom, DENOM_EPS)
        arg = jnp.where(denom > DENOM_EPS, proj, w)
        q = nearest_level(arg, alpha, M)
        u_next = u + y[:, None] * w[None, :] - yt[:, None] * q[None, :]
        return u_next, q

    u0 = jnp.zeros((m, B), jnp.float32)
    _, Q = jax.lax.scan(step, u0, (Y.T, Yt.T, W))
    q_ref[...] = Q


def gpfq_quantize(Y, Yt, W, alpha, *, M: int, block_b: int | None = None):
    """Quantize all neurons (columns of W) with GPFQ via the Pallas kernel.

    Y, Yt : (m, N) float32;  W : (N, n) float32;  alpha : scalar.
    Returns Q : (N, n) float32 with entries in alpha*{-1+2j/(M-1)}.

    The neuron axis n must be divisible by ``block_b`` (the Rust coordinator
    pads with zero neurons; quantizing a zero neuron yields the zero vector,
    so padding is harmless and sliced off by the caller).
    """
    m, N = Y.shape
    n = W.shape[1]
    if block_b is None:
        block_b = min(n, 64)
    if n % block_b != 0:
        raise ValueError(f"neuron count {n} not divisible by block {block_b}")
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_gpfq_kernel, M=M)
    return pl.pallas_call(
        kernel,
        grid=(n // block_b,),
        in_specs=[
            pl.BlockSpec((m, N), lambda i: (0, 0)),
            pl.BlockSpec((m, N), lambda i: (0, 0)),
            pl.BlockSpec((N, block_b), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((N, block_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((N, n), jnp.float32),
        interpret=True,
    )(Y, Yt, W, alpha_arr)


def gpfq_first_layer(X, W, alpha, *, M: int, block_b: int | None = None):
    """Paper eq. (2): first-layer quantization, where Y~ = Y = X."""
    return gpfq_quantize(X, X, W, alpha, M=M, block_b=block_b)
