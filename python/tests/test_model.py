"""L2 graph tests: shapes, numerics, training dynamics, conv-as-im2col."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed: model graph tests skipped")
import jax
import jax.numpy as jnp

from compile import model


class TestDense:
    def test_dense_fwd_relu(self):
        Y = jnp.asarray([[1.0, -1.0]], jnp.float32)
        W = jnp.eye(2, dtype=jnp.float32)
        b = jnp.asarray([0.5, 0.5], jnp.float32)
        (out,) = model.dense_fwd(Y, W, b, act="relu")
        assert np.allclose(np.asarray(out), [[1.5, 0.0]])

    def test_dense_fwd_none_keeps_negatives(self):
        Y = jnp.asarray([[-2.0]], jnp.float32)
        W = jnp.asarray([[1.0]], jnp.float32)
        b = jnp.zeros((1,), jnp.float32)
        (out,) = model.dense_fwd(Y, W, b, act="none")
        assert float(out[0, 0]) == -2.0

    def test_unknown_activation_raises(self):
        with pytest.raises(ValueError):
            model.activate(jnp.zeros((1,)), "gelu")


class TestMlp:
    DIMS = (12, 8, 5)

    def params(self, seed=0):
        return model.init_mlp_params(jax.random.PRNGKey(seed), self.DIMS)

    def test_forward_shape(self):
        p = self.params()
        x = jnp.zeros((7, 12), jnp.float32)
        (logits,) = model.mlp_fwd(x, *p, dims=self.DIMS)
        assert logits.shape == (7, 5)

    def test_forward_matches_manual(self):
        p = self.params(1)
        x = np.random.default_rng(0).normal(size=(3, 12)).astype(np.float32)
        (logits,) = model.mlp_fwd(jnp.asarray(x), *p, dims=self.DIMS)
        W1, b1, W2, b2 = (np.asarray(a) for a in p)
        h = np.maximum(x @ W1 + b1, 0.0)
        want = h @ W2 + b2
        assert np.allclose(np.asarray(logits), want, atol=1e-5)

    def test_param_count_interleaving(self):
        p = self.params()
        assert len(p) == 4
        assert p[0].shape == (12, 8) and p[1].shape == (8,)
        assert p[2].shape == (8, 5) and p[3].shape == (5,)


class TestTrainStep:
    DIMS = (10, 16, 4)

    def test_one_step_shapes(self):
        p = model.init_mlp_params(jax.random.PRNGKey(0), self.DIMS)
        x = jnp.zeros((6, 10), jnp.float32)
        y = jax.nn.one_hot(jnp.asarray([0, 1, 2, 3, 0, 1]), 4)
        out = model.train_step(*p, x, y, jnp.float32(0.1), dims=self.DIMS)
        assert len(out) == len(p) + 1
        for a, b in zip(out[:-1], p):
            assert a.shape == b.shape
        assert out[-1].shape == ()

    def test_loss_decreases(self):
        rng = np.random.default_rng(0)
        # a linearly separable toy problem
        x = rng.normal(size=(64, 10)).astype(np.float32)
        labels = (x[:, 0] > 0).astype(np.int32) + 2 * (x[:, 1] > 0).astype(np.int32)
        y = np.asarray(jax.nn.one_hot(labels, 4))
        params = model.init_mlp_params(jax.random.PRNGKey(1), self.DIMS)
        losses = []
        for _ in range(60):
            out = model.train_step(*params, x, y, jnp.float32(0.5), dims=self.DIMS)
            params = list(out[:-1])
            losses.append(float(out[-1]))
        assert losses[-1] < 0.5 * losses[0], (losses[0], losses[-1])

    def test_zero_lr_is_identity(self):
        p = model.init_mlp_params(jax.random.PRNGKey(2), self.DIMS)
        x = jnp.ones((4, 10), jnp.float32)
        y = jax.nn.one_hot(jnp.asarray([0, 1, 2, 3]), 4)
        out = model.train_step(*p, x, y, jnp.float32(0.0), dims=self.DIMS)
        for a, b in zip(out[:-1], p):
            assert np.allclose(np.asarray(a), np.asarray(b))


class TestConvIm2col:
    def test_im2col_shape(self):
        X = jnp.zeros((2, 8, 8, 3), jnp.float32)
        cols = model.im2col(X, 3, 3, stride=1)
        assert cols.shape == (2 * 6 * 6, 27)

    def test_conv_matches_lax_conv(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(2, 9, 9, 3)).astype(np.float32)
        K4 = rng.normal(size=(3, 3, 3, 5)).astype(np.float32)  # (kh,kw,cin,cout)
        b = rng.normal(size=(5,)).astype(np.float32)
        Kflat = K4.reshape(27, 5)
        (got,) = model.conv2d_fwd(jnp.asarray(X), jnp.asarray(Kflat), jnp.asarray(b), stride=1, act="none")
        want = jax.lax.conv_general_dilated(
            jnp.asarray(X), jnp.asarray(K4), (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + b[None, None, None, :]
        assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_conv_stride2(self):
        rng = np.random.default_rng(1)
        X = rng.normal(size=(1, 8, 8, 2)).astype(np.float32)
        K4 = rng.normal(size=(2, 2, 2, 3)).astype(np.float32)
        b = np.zeros((3,), np.float32)
        (got,) = model.conv2d_fwd(jnp.asarray(X), jnp.asarray(K4.reshape(8, 3)), jnp.asarray(b), stride=2, act="none")
        want = jax.lax.conv_general_dilated(
            jnp.asarray(X), jnp.asarray(K4), (2, 2), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        assert got.shape == want.shape == (1, 4, 4, 3)
        assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_relu_applied(self):
        X = -jnp.ones((1, 3, 3, 1), jnp.float32)
        K = jnp.ones((9, 1), jnp.float32)
        b = jnp.zeros((1,), jnp.float32)
        (out,) = model.conv2d_fwd(X, K, b, act="relu")
        assert float(out.min()) == 0.0
