"""AOT emitter tests: manifests are consistent, HLO text is well-formed."""

import json
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed: AOT emitter tests skipped")
import jax
import jax.numpy as jnp

from compile import aot, model


class TestSpecs:
    def test_default_specs_unique_names(self):
        specs = aot.default_specs()
        names = [s.name for s in specs]
        assert len(names) == len(set(names))
        assert len(specs) >= 15

    def test_quick_subset_nonempty_and_covers_kinds(self):
        quick = [s for s in aot.default_specs() if s.quick]
        kinds = {s.kind for s in quick}
        assert {"gpfq", "msq", "dense", "mlp_fwd", "train_step"} <= kinds

    def test_manifest_entry_shapes(self):
        s = aot.gpfq_spec(8, 16, 4, 3)
        e = s.manifest_entry()
        assert e["name"] == "gpfq_m8_n16_b4_M3"
        assert e["params"][0] == {"name": "Y", "shape": [8, 16], "dtype": "f32"}
        assert e["outputs"] == [{"shape": [16, 4], "dtype": "f32"}]
        assert e["meta"]["M"] == 3


class TestEmission:
    def test_emit_gpfq_hlo_text(self, tmp_path):
        s = aot.gpfq_spec(8, 16, 4, 3)
        path = aot.emit(s, str(tmp_path))
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # scan lowers to a while loop over the t axis
        assert "while" in text

    def test_emit_dense_hlo_text(self, tmp_path):
        s = aot.dense_spec(8, 16, 4, "relu")
        path = aot.emit(s, str(tmp_path))
        text = open(path).read()
        assert "dot" in text and "maximum" in text

    def test_main_quick_writes_manifest(self, tmp_path):
        rc = aot.main(["--out", str(tmp_path), "--quick"])
        assert rc == 0
        man = json.load(open(tmp_path / "manifest.json"))
        assert man["version"] == 1
        assert len(man["artifacts"]) >= 5
        for a in man["artifacts"]:
            assert os.path.exists(tmp_path / a["file"]), a["file"]
            assert a["kind"] in ("gpfq", "msq", "dense", "mlp_fwd", "train_step")

    def test_only_filter(self, tmp_path):
        rc = aot.main(["--out", str(tmp_path), "--only", "msq_n784"])
        assert rc == 0
        man = json.load(open(tmp_path / "manifest.json"))
        assert [a["name"] for a in man["artifacts"]] == ["msq_n784_b64_M3"]


class TestLoweredNumerics:
    """Compile the lowered artifact with jax's own backend and compare with
    direct execution -- catches lowering bugs before the Rust round-trip."""

    def test_gpfq_artifact_numerics(self):
        s = aot.gpfq_spec(8, 16, 4, 3)
        rng = np.random.default_rng(0)
        Y = rng.normal(size=(8, 16)).astype(np.float32)
        Yt = (Y + 0.1 * rng.normal(size=(8, 16))).astype(np.float32)
        W = rng.uniform(-1, 1, size=(16, 4)).astype(np.float32)
        alpha = np.float32(0.8)
        direct = s.fn(Y, Yt, W, alpha)[0]
        compiled = jax.jit(s.fn).lower(Y, Yt, W, alpha).compile()(Y, Yt, W, alpha)[0]
        assert np.allclose(np.asarray(direct), np.asarray(compiled))

    def test_train_step_artifact_numerics(self):
        dims = (6, 5, 3)
        s = aot.train_spec(4, dims)
        params = model.init_mlp_params(jax.random.PRNGKey(0), dims)
        x = np.random.default_rng(1).normal(size=(4, 6)).astype(np.float32)
        y = np.asarray(jax.nn.one_hot(jnp.asarray([0, 1, 2, 0]), 3))
        args = (*params, x, y, np.float32(0.1))
        direct = s.fn(*args)
        compiled = jax.jit(s.fn).lower(*args).compile()(*args)
        for d, c in zip(direct, compiled):
            assert np.allclose(np.asarray(d), np.asarray(c), atol=1e-6)
