"""Gating tests for the static-analysis pass (`python/tools/lint.py`, the
mirror of `rust/src/analysis/` — see docs/LINTS.md).

These tests ARE the lint gate in toolchain-less containers: the full repo
must lint clean, every positive fixture must trip exactly its own rule,
every negative fixture must be silent, and `rust/oracles.lock` must pin the
frozen oracle sources byte-for-byte (a one-character tamper is caught).
"""

import importlib.util
import os
import shutil
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
FIXTURES = os.path.join(REPO, "rust", "tests", "lint_fixtures")


def _load_lint():
    path = os.path.join(REPO, "python", "tools", "lint.py")
    spec = importlib.util.spec_from_file_location("gpfq_lint", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("gpfq_lint", mod)
    spec.loader.exec_module(mod)
    return mod


lint = _load_lint()


# --------------------------------------------------------------------------
# the gate: the repo itself
# --------------------------------------------------------------------------


def test_full_repo_lints_clean():
    active, _allowed, stale = lint.run_lint(REPO)
    msgs = [f"{f.path}:{f.line}: [{f.rule}] {f.message}" for f in active]
    assert not active, "lint findings on the real repo:\n" + "\n".join(msgs)
    assert not stale, "stale allowlist entries: lines " + ", ".join(
        str(e.line) for e in stale
    )


def test_every_allowlist_entry_is_justified():
    config = []
    entries = lint.parse_allowlist(
        os.path.join(REPO, lint.ALLOWLIST_PATH), config
    )
    assert not config, [f.message for f in config]
    assert entries, "allowlist parsed empty — format drift?"
    for e in entries:
        assert e.rule in lint.ALLOWLISTABLE
        assert len(e.justification) >= 10, (
            f"line {e.line}: justification too thin: {e.justification!r}"
        )


# --------------------------------------------------------------------------
# fixture corpus: one positive + one negative mini-root per rule
# --------------------------------------------------------------------------

CASES = [
    ("oracle_freeze_positive", "oracle-freeze"),
    ("panic_path_positive", "panic-path"),
    ("lock_discipline_positive", "lock-discipline"),
    ("float_determinism_positive", "float-determinism"),
    ("zero_dep_positive", "zero-dep"),
]


@pytest.mark.parametrize("case,rule", CASES)
def test_positive_fixture_trips_its_rule(case, rule):
    root = os.path.join(FIXTURES, case)
    active, _, _ = lint.run_lint(root)
    assert active, f"{case}: expected findings, got none"
    rules = {f.rule for f in active}
    assert rules == {rule}, f"{case}: expected only {rule!r}, got {rules}"
    # the CLI surface agrees with the library surface
    assert lint.main(["--root", root]) == 1


@pytest.mark.parametrize(
    "case",
    [c.replace("_positive", "_negative") for c, _ in CASES],
)
def test_negative_fixture_is_clean(case):
    root = os.path.join(FIXTURES, case)
    active, _, stale = lint.run_lint(root)
    msgs = [f"{f.path}:{f.line}: [{f.rule}] {f.message}" for f in active]
    assert not active, f"{case}:\n" + "\n".join(msgs)
    assert not stale
    assert lint.main(["--root", root]) == 0


def test_lock_positive_covers_all_three_shapes():
    root = os.path.join(FIXTURES, "lock_discipline_positive")
    active, _, _ = lint.run_lint(root)
    msgs = " | ".join(f.message for f in active)
    assert "nested .lock()" in msgs
    assert "condvar wait outside a predicate loop" in msgs
    assert "I/O while lock guard" in msgs


# --------------------------------------------------------------------------
# oracle manifest: pins the live sources, catches a one-character tamper
# --------------------------------------------------------------------------


def test_oracle_manifest_matches_current_sources():
    pinned = lint.parse_manifest(os.path.join(REPO, lint.MANIFEST_PATH))
    current = lint.compute_manifest(REPO)
    assert pinned == current, (
        "rust/oracles.lock disagrees with the frozen oracle sources; "
        "if the oracle edit is intentional run "
        "`python3 python/tools/lint.py --fix-manifest` in the same change"
    )
    # every declared oracle item actually resolved to a source span
    assert set(current) == {f"{rel}::{item}" for rel, item in lint.ORACLE_ITEMS}


def test_one_char_tamper_is_caught(tmp_path):
    # copy the pristine oracle fixture, flip one character in matmul_naive,
    # and the oracle-freeze rule must fire (the acceptance criterion)
    src = os.path.join(FIXTURES, "oracle_freeze_negative")
    root = tmp_path / "mini"
    shutil.copytree(src, root)
    target = root / "rust" / "src" / "nn" / "matrix.rs"
    text = target.read_text()
    assert "+=" in text
    target.write_text(text.replace("+=", "-=", 1))
    active, _, _ = lint.run_lint(str(root))
    assert [f.rule for f in active] == ["oracle-freeze"]
    assert "drifted" in active[0].message


def test_item_extraction_is_whitespace_normalized_but_content_sensitive():
    src = lint.SourceFile(
        "x.rs",
        "fn f(a: u32) -> u32 {\n    a + 1\n}\n",
    )
    base = lint.extract_item(src, "f")
    trailing_ws = lint.SourceFile(
        "x.rs",
        "fn f(a: u32) -> u32 {   \n    a + 1\n}\n",
    )
    assert lint.extract_item(trailing_ws, "f") == base
    changed = lint.SourceFile(
        "x.rs",
        "fn f(a: u32) -> u32 {\n    a + 2\n}\n",
    )
    assert lint.extract_item(changed, "f") != base


# --------------------------------------------------------------------------
# scanner details both runners must agree on
# --------------------------------------------------------------------------


def test_strip_source_ignores_comments_strings_and_lifetimes():
    text = (
        '// unwrap() in a comment\n'
        'let s = "panic!(not real)";\n'
        "fn f<'a>(x: &'a str) {}\n"
        "/* block .lock() comment */\n"
        "let c = '\"';\n"
        "real.unwrap();\n"
    )
    stripped = lint.strip_source(text)
    lines = stripped.split("\n")
    assert "unwrap" not in lines[0]
    assert "panic" not in lines[1]
    assert "'a" in lines[2]  # lifetime survives
    assert ".lock(" not in lines[3]
    assert ".unwrap()" in lines[5]


def test_test_regions_are_skipped():
    text = (
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    fn t() { x.unwrap(); }\n"
        "}\n"
        "fn live() {}\n"
    )
    src = lint.SourceFile("rust/src/serve/http.rs", text)
    assert src.is_test[2]
    assert not src.is_test[4]
