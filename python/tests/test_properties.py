"""Hypothesis property tests over the L1 kernels: algorithmic invariants
beyond the pointwise kernel-vs-oracle checks in test_kernel.py."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed: property tests skipped")
pytest.importorskip("jax", reason="jax not installed: kernel tests skipped")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gpfq import gpfq_quantize, nearest_level
from compile.kernels.msq import msq_quantize
from compile.kernels.ref import alphabet, gpfq_ref, msq_ref


def rand(seed, *shape, lo=None, hi=None):
    rng = np.random.default_rng(seed)
    if lo is None:
        return rng.normal(size=shape).astype(np.float32)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


class TestGpfqInvariants:
    @given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from([4, 12]), n=st.sampled_from([8, 24]))
    @settings(max_examples=20, deadline=None)
    def test_state_identity(self, seed, m, n):
        # ||u_N|| == ||Yw - Y~q|| recomputed from scratch
        Y = rand(seed, m, n)
        Yt = Y + 0.1 * rand(seed + 1, m, n)
        W = rand(seed + 2, n, 4, lo=-1, hi=1)
        Q, U = gpfq_ref(Y, Yt, W, 1.0, 3)
        direct = np.linalg.norm(Y @ W - Yt @ np.asarray(Q), axis=0)
        state = np.linalg.norm(np.asarray(U), axis=0)
        assert np.allclose(direct, state, rtol=1e-3, atol=1e-4)

    @given(
        seed=st.integers(0, 2**31 - 1),
        c=st.floats(0.25, 4.0),
        M=st.sampled_from([3, 4, 8]),
    )
    @settings(max_examples=20, deadline=None)
    def test_scale_equivariance(self, seed, c, M):
        # quantize(c*W, alpha=c) == c * quantize(W, alpha=1)
        Y = rand(seed, 8, 16)
        W = rand(seed + 1, 16, 4, lo=-1, hi=1)
        q1 = np.asarray(gpfq_quantize(Y, Y, W, np.float32(1.0), M=M, block_b=4))
        q2 = np.asarray(
            gpfq_quantize(Y, Y, (c * W).astype(np.float32), np.float32(c), M=M, block_b=4)
        )
        assert np.allclose(c * q1, q2, rtol=1e-4, atol=1e-5 * c)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_neuron_permutation_covariance(self, seed):
        Y = rand(seed, 10, 20)
        W = rand(seed + 1, 20, 6, lo=-1, hi=1)
        Q = np.asarray(gpfq_quantize(Y, Y, W, 1.0, M=3, block_b=6))
        perm = np.random.default_rng(seed).permutation(6)
        Qp = np.asarray(gpfq_quantize(Y, Y, W[:, perm], 1.0, M=3, block_b=6))
        assert np.allclose(Q[:, perm], Qp)

    @given(seed=st.integers(0, 2**31 - 1), M=st.sampled_from([2, 3, 16]))
    @settings(max_examples=15, deadline=None)
    def test_row_scaling_invariance_of_decision(self, seed, M):
        # scaling the whole data matrix by a positive constant leaves the
        # argmin decisions unchanged (the projection is scale invariant)
        Y = rand(seed, 8, 16)
        W = rand(seed + 1, 16, 4, lo=-1, hi=1)
        q1 = np.asarray(gpfq_quantize(Y, Y, W, 1.0, M=M, block_b=4))
        q2 = np.asarray(gpfq_quantize(5.0 * Y, 5.0 * Y, W, 1.0, M=M, block_b=4))
        assert np.allclose(q1, q2)


class TestMsqInvariants:
    @given(
        seed=st.integers(0, 2**31 - 1),
        alpha=st.floats(0.2, 3.0),
        M=st.sampled_from([2, 3, 4, 16]),
    )
    @settings(max_examples=30, deadline=None)
    def test_msq_minimizes_elementwise_distance(self, seed, alpha, M):
        W = rand(seed, 12, 4, lo=-2, hi=2)
        Q = np.asarray(msq_quantize(W, np.float32(alpha), M=M, block_b=4))
        A = np.asarray(alphabet(M, alpha))
        best = A[np.argmin(np.abs(W[..., None] - A), axis=-1)]
        assert np.allclose(np.abs(Q - W), np.abs(best - W), atol=1e-5)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_msq_is_odd_function(self, seed):
        W = rand(seed, 10, 4, lo=-1.5, hi=1.5)
        a = np.float32(0.9)
        q_pos = np.asarray(msq_ref(W, a, 4))
        q_neg = np.asarray(msq_ref(-W, a, 4))
        assert np.allclose(q_pos, -q_neg, atol=1e-6)


class TestNearestLevel:
    @given(
        z=st.floats(-5, 5),
        alpha=st.floats(0.1, 3.0),
        M=st.sampled_from([2, 3, 4, 8, 16]),
    )
    @settings(max_examples=100, deadline=None)
    def test_error_at_most_half_step(self, z, alpha, M):
        q = float(nearest_level(jnp.float32(z), jnp.float32(alpha), M))
        step = 2 * alpha / (M - 1)
        zc = np.clip(np.float32(z), -alpha, alpha)
        assert abs(q - zc) <= step / 2 + 1e-5

    @given(alpha=st.floats(0.1, 3.0), M=st.sampled_from([3, 4, 16]))
    @settings(max_examples=30, deadline=None)
    def test_monotone(self, alpha, M):
        zs = np.linspace(-2 * alpha, 2 * alpha, 41, dtype=np.float32)
        qs = np.asarray(nearest_level(jnp.asarray(zs), jnp.float32(alpha), M))
        assert np.all(np.diff(qs) >= -1e-6)
