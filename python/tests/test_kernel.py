"""Kernel-vs-oracle tests: the CORE correctness signal of layer L1.

The Pallas kernel implements the concise Lemma 1 projection form; the
reference implements the definitional brute-force argmin of eq. (2)/(3).
Exact agreement on generic float data is therefore a numerical verification
of Lemma 1 on top of a kernel correctness check.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed: property tests skipped")
pytest.importorskip("jax", reason="jax not installed: kernel tests skipped")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import (
    DENOM_EPS,
    alphabet,
    gpfq_error_ref,
    gpfq_ref,
    median_alpha,
    msq_ref,
)
from compile.kernels.gpfq import gpfq_first_layer, gpfq_quantize, nearest_level
from compile.kernels.msq import msq_quantize


def rand_problem(seed, m, n, b, scale_w=1.0, yt_noise=0.05):
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(m, n)).astype(np.float32)
    Yt = (Y + yt_noise * rng.normal(size=(m, n))).astype(np.float32)
    W = (scale_w * rng.uniform(-1, 1, size=(n, b))).astype(np.float32)
    return Y, Yt, W


# ---------------------------------------------------------------------------
# alphabet / nearest_level
# ---------------------------------------------------------------------------

class TestAlphabet:
    def test_ternary_levels(self):
        A = np.asarray(alphabet(3, 2.0))
        assert np.allclose(A, [-2.0, 0.0, 2.0])

    def test_levels_equispaced_and_symmetric(self):
        for M in (2, 3, 4, 8, 16):
            A = np.asarray(alphabet(M, 1.5))
            d = np.diff(A)
            assert np.allclose(d, d[0], atol=1e-6), M
            assert np.allclose(A, -A[::-1], atol=1e-6), M
            assert A.min() == pytest.approx(-1.5) and A.max() == pytest.approx(1.5)

    def test_invalid_M(self):
        with pytest.raises(ValueError):
            alphabet(1, 1.0)

    @given(
        z=st.floats(-10, 10),
        alpha=st.floats(0.1, 5.0),
        M=st.sampled_from([2, 3, 4, 8, 16]),
    )
    @settings(max_examples=200, deadline=None)
    def test_nearest_level_is_argmin(self, z, alpha, M):
        A = np.asarray(alphabet(M, alpha))
        got = float(nearest_level(jnp.float32(z), jnp.float32(alpha), M))
        best = A[np.argmin(np.abs(A - np.float32(z)))]
        # allow ties: got must be *a* minimizer
        assert abs(abs(got - np.float32(z)) - abs(best - np.float32(z))) <= 1e-5

    @given(alpha=st.floats(0.1, 5.0), M=st.sampled_from([2, 3, 4, 8, 16]))
    @settings(max_examples=50, deadline=None)
    def test_nearest_level_idempotent_on_alphabet(self, alpha, M):
        A = alphabet(M, alpha)
        again = nearest_level(A, jnp.float32(alpha), M)
        assert np.allclose(np.asarray(A), np.asarray(again), atol=1e-5)

    def test_median_alpha(self):
        W = jnp.asarray([[0.1, -0.2], [0.3, -0.4]], jnp.float32)
        assert float(median_alpha(W, 2.0)) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# MSQ kernel vs oracle
# ---------------------------------------------------------------------------

class TestMsqKernel:
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([8, 24, 64]),
        b=st.sampled_from([4, 8]),
        M=st.sampled_from([2, 3, 4, 16]),
        alpha=st.floats(0.2, 3.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_ref(self, seed, n, b, M, alpha):
        rng = np.random.default_rng(seed)
        W = rng.uniform(-2, 2, size=(n, b)).astype(np.float32)
        ref = np.asarray(msq_ref(W, alpha, M))
        got = np.asarray(msq_quantize(W, np.float32(alpha), M=M, block_b=b))
        assert np.allclose(ref, got, atol=1e-5)

    def test_output_in_alphabet(self):
        rng = np.random.default_rng(7)
        W = rng.normal(size=(32, 8)).astype(np.float32)
        M, alpha = 4, 1.3
        Q = np.asarray(msq_quantize(W, alpha, M=M, block_b=8))
        A = np.asarray(alphabet(M, alpha))
        dist = np.min(np.abs(Q[..., None] - A), axis=-1)
        assert dist.max() < 1e-5


# ---------------------------------------------------------------------------
# GPFQ kernel vs oracle (Lemma 1 verification)
# ---------------------------------------------------------------------------

class TestGpfqKernel:
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.sampled_from([4, 16, 48]),
        n=st.sampled_from([8, 32, 96]),
        b=st.sampled_from([4, 8]),
        M=st.sampled_from([3, 4, 8, 16]),
        alpha=st.floats(0.3, 2.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_matches_bruteforce_ref(self, seed, m, n, b, M, alpha):
        Y, Yt, W = rand_problem(seed, m, n, b)
        Qr, _ = gpfq_ref(Y, Yt, W, np.float32(alpha), M)
        Qk = gpfq_quantize(Y, Yt, W, np.float32(alpha), M=M, block_b=b)
        assert np.allclose(np.asarray(Qr), np.asarray(Qk), atol=1e-5)

    def test_first_layer_is_yt_eq_y(self):
        Y, _, W = rand_problem(3, 16, 24, 8)
        a = gpfq_first_layer(Y, W, 1.0, M=3, block_b=8)
        b = gpfq_quantize(Y, Y, W, 1.0, M=3, block_b=8)
        assert np.allclose(np.asarray(a), np.asarray(b))

    def test_output_in_alphabet(self):
        Y, Yt, W = rand_problem(11, 16, 40, 8)
        M, alpha = 8, 0.9
        Q = np.asarray(gpfq_quantize(Y, Yt, W, alpha, M=M, block_b=8))
        A = np.asarray(alphabet(M, alpha))
        dist = np.min(np.abs(Q[..., None] - A), axis=-1)
        assert dist.max() < 1e-5

    def test_neuron_blocks_independent(self):
        # quantizing with different block widths must give identical results:
        # GPFQ treats each neuron independently (paper Section 4).
        Y, Yt, W = rand_problem(5, 12, 20, 8)
        q1 = np.asarray(gpfq_quantize(Y, Yt, W, 0.8, M=3, block_b=2))
        q2 = np.asarray(gpfq_quantize(Y, Yt, W, 0.8, M=3, block_b=8))
        assert np.allclose(q1, q2)

    def test_zero_column_padding_is_noop(self):
        # the coordinator pads the t axis with zero columns / zero weights to
        # hit bucketed artifact shapes; this must not change the real rows.
        Y, Yt, W = rand_problem(9, 16, 24, 4)
        pad = 8
        Yp = np.concatenate([Y, np.zeros((16, pad), np.float32)], axis=1)
        Ytp = np.concatenate([Yt, np.zeros((16, pad), np.float32)], axis=1)
        Wp = np.concatenate([W, np.zeros((pad, 4), np.float32)], axis=0)
        Q = np.asarray(gpfq_quantize(Y, Yt, W, 1.0, M=3, block_b=4))
        Qp = np.asarray(gpfq_quantize(Yp, Ytp, Wp, 1.0, M=3, block_b=4))
        assert np.allclose(Q, Qp[:24])
        assert np.allclose(Qp[24:], 0.0)

    def test_zero_neuron_padding_quantizes_to_zero(self):
        Y, Yt, _ = rand_problem(13, 16, 24, 4)
        W = np.zeros((24, 4), np.float32)
        Q = np.asarray(gpfq_quantize(Y, Yt, W, 1.0, M=3, block_b=4))
        assert np.allclose(Q, 0.0)

    def test_already_quantized_weights_are_fixed_point(self):
        # if w already has entries in the alphabet and Yt == Y, GPFQ must
        # return q == w (u stays 0 so the projection equals w_t exactly).
        rng = np.random.default_rng(17)
        Y = rng.normal(size=(16, 24)).astype(np.float32)
        A = np.asarray(alphabet(3, 1.0))
        W = A[rng.integers(0, 3, size=(24, 4))].astype(np.float32)
        Q = np.asarray(gpfq_quantize(Y, Y, W, 1.0, M=3, block_b=4))
        assert np.allclose(Q, W)

    def test_sigma_delta_degenerate_case(self):
        # paper Section 4: if all columns X_t are identical, GPFQ reduces to
        # a first-order greedy sigma-delta quantizer and ||u_t|| <= ||X||/2.
        rng = np.random.default_rng(23)
        x = rng.normal(size=(16,)).astype(np.float32)
        n = 40
        Y = np.tile(x[:, None], (1, n))
        w = rng.uniform(-1, 1, size=(n, 1)).astype(np.float32)
        _, U = gpfq_ref(Y, Y, w, 1.0, 3)
        # final state is (sum_t w_t - q_t) x with |sum| <= 1/2
        resid = np.linalg.norm(np.asarray(U)) / np.linalg.norm(x)
        assert resid <= 0.5 + 1e-5


# ---------------------------------------------------------------------------
# error behaviour (theory smoke: Theorem 2 shape)
# ---------------------------------------------------------------------------

class TestErrorBehaviour:
    def test_gpfq_beats_msq_on_gaussian_data(self):
        # median over seeds of the relative error; the paper's headline
        # comparison (Figure 1 / Table 1) at small scale.
        errs_g, errs_m = [], []
        for seed in range(8):
            Y, _, W = rand_problem(seed, 32, 256, 8)
            e_g = np.median(np.asarray(gpfq_error_ref(Y, Y, W, 1.0, 3)))
            Qm = np.asarray(msq_ref(W, 1.0, 3))
            num = np.linalg.norm(Y @ W - Y @ Qm, axis=0)
            den = np.linalg.norm(Y @ W, axis=0)
            errs_g.append(e_g)
            errs_m.append(np.median(num / den))
        assert np.median(errs_g) < 0.7 * np.median(errs_m)

    def test_relative_error_decays_with_overparametrization(self):
        # Theorem 2: for fixed m, relative error ~ log(N) sqrt(m/N).
        m = 16
        med = {}
        for N in (64, 1024):
            es = []
            for seed in range(6):
                Y, _, W = rand_problem(seed, m, N, 4)
                es.append(np.median(np.asarray(gpfq_error_ref(Y, Y, W, 1.0, 3))))
            med[N] = np.median(es)
        assert med[1024] < 0.5 * med[64], med
