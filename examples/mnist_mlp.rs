//! E1/E2 — the paper's Section 6.1 MNIST experiment (Figures 1a and 1b),
//! on the synthetic MNIST stand-in (DESIGN.md §5).
//!
//!     cargo run --release --example mnist_mlp [-- --paper-scale]
//!
//! Figure 1a: ternary test accuracy vs alphabet scalar C_alpha ∈ {1..10}
//! for GPFQ vs MSQ, as **mean ± std over 3 independent draws** of the
//! quantization sample set (the paper's error bars, via `TrialSet`).
//! Figure 1b: test accuracy as layers are quantized one at a time with
//! each method's best C_alpha — GPFQ "error-corrects" because layer ℓ is
//! quantized against the Ỹ stream of Q^(1..ℓ-1).

use gpfq::config::{preset_mnist, preset_mnist_paper};
use gpfq::coordinator::pipeline::{quantize_network, Method, PipelineConfig};
use gpfq::coordinator::sweep::{sweep_trials, SweepConfig};
use gpfq::coordinator::TrialSet;
use gpfq::data::synth::{generate, mnist_like_spec};
use gpfq::eval::metrics::accuracy;
use gpfq::eval::report::acc;
use gpfq::train::train;
use gpfq::util::bench::Table;

fn main() {
    let paper_scale = std::env::args().any(|a| a == "--paper-scale");
    let spec = if paper_scale { preset_mnist_paper(0) } else { preset_mnist(0) };
    let sspec = mnist_like_spec(spec.seed);
    let train_set = generate(&sspec, spec.dataset.n_train, 0, false);
    let test_set = generate(&sspec, spec.dataset.n_test, 1, false);

    let mut net = spec.build_network();
    println!("training {} on {} samples ...", net.summary(), train_set.len());
    train(&mut net, &train_set, &spec.train);
    // trial 0 is the training prefix (the deterministic single-trial sample
    // set); trials 1–2 draw distinct rows on their own PCG streams
    let n_quant = spec.dataset.n_quant.min(train_set.len());
    let trials = TrialSet::draw(&train_set.x, n_quant, 3, spec.seed);
    let x_quant = trials.sample_set(0);

    // ---- Figure 1a: accuracy vs C_alpha, ternary, mean ± std over trials --
    let cfg = SweepConfig {
        levels: vec![3],
        c_alphas: spec.quant.c_alphas.clone(),
        methods: vec![Method::Gpfq, Method::Msq],
        workers: spec.quant.workers,
        ..Default::default()
    };
    let res = sweep_trials(&net, &trials, &test_set, &cfg);
    let mut fig1a = Table::new(
        &format!(
            "Figure 1a — MNIST-like MLP, ternary, {} trials (analog top-1 {})",
            res.trials,
            acc(res.analog_top1)
        ),
        &["C_alpha", "GPFQ mean±std", "MSQ mean±std"],
    );
    for &c in &spec.quant.c_alphas {
        let g = res.points.iter().find(|p| p.method == Method::Gpfq && p.c_alpha_requested == c).unwrap();
        let m = res.points.iter().find(|p| p.method == Method::Msq && p.c_alpha_requested == c).unwrap();
        fig1a.row(vec![
            format!("{c}"),
            format!("{:.4}±{:.4}", g.top1_stats.mean, g.top1_stats.std),
            format!("{:.4}±{:.4}", m.top1_stats.mean, m.top1_stats.std),
        ]);
    }
    fig1a.emit("fig1a_mnist");
    println!(
        "accuracy spread over C_alpha:  GPFQ {:.4}   MSQ {:.4}  (paper: MSQ is unstable, GPFQ is not)\n",
        res.spread(Method::Gpfq, 3),
        res.spread(Method::Msq, 3)
    );

    // ---- Figure 1b: layer-by-layer progression at each method's best ------
    let mut fig1b = Table::new(
        "Figure 1b — accuracy as layers are successively quantized",
        &["layers quantized", "GPFQ top-1", "MSQ top-1"],
    );
    let best = |m: Method| res.best(m).map(|p| p.c_alpha_f32()).unwrap_or(2.0);
    let mut cols: Vec<Vec<f64>> = Vec::new();
    let mut gpfq_outcome = None;
    for method in [Method::Gpfq, Method::Msq] {
        let cfg = PipelineConfig {
            method,
            c_alpha: best(method),
            capture_checkpoints: true,
            ..Default::default()
        };
        let out = quantize_network(&net, &x_quant, &cfg);
        cols.push(out.checkpoints.iter().map(|net| accuracy(net, &test_set)).collect());
        if method == Method::Gpfq {
            gpfq_outcome = Some(out);
        }
    }
    for i in 0..cols[0].len() {
        fig1b.row(vec![(i + 1).to_string(), acc(cols[0][i]), acc(cols[1][i])]);
    }
    fig1b.emit("fig1b_mnist");
    let g_last = *cols[0].last().unwrap();
    let g_min = cols[0].iter().cloned().fold(f64::MAX, f64::min);
    if g_last > g_min {
        println!("GPFQ recovered {:+.4} top-1 after its worst intermediate layer — the Figure 1b error-correction effect.", g_last - g_min);
    }

    // ---- deployable artifact: pack the best GPFQ network and say how to
    // ---- serve it (the point of the 20x compression)
    let out = gpfq_outcome.expect("gpfq ran");
    let hints = gpfq::nn::serialize::hints_from_outcome(&out);
    let path = std::path::Path::new("results/mnist_mlp.gpfq");
    let _ = std::fs::create_dir_all("results");
    match gpfq::nn::serialize::save_file(&out.network, &hints, path) {
        Ok(bytes) => {
            println!("\npacked model written: {} ({bytes} bytes, ternary weights bit-packed)", path.display());
            println!("serve it:  gpfq serve --model {} --port 8080", path.display());
            println!("load-test: gpfq bench-serve --model {}", path.display());
        }
        Err(e) => eprintln!("warning: could not write {}: {e:#}", path.display()),
    }
}
