//! E7/E8/E9 — validate the paper's theory numerically:
//!
//!  * Theorem 2: relative training error decays like log(N₀)·√(m/N₀)
//!    as the overparameterization N₀ grows (Gaussian data).
//!  * Theorem 3 / Remark 4: generalization error |z^T(w−q)| for z drawn
//!    from the span of the training data stays controlled.
//!  * Lemma 16: for data in a d-dimensional subspace, the error tracks the
//!    intrinsic dimension d, not the ambient sample count m.
//!
//!     cargo run --release --example theory_validation

use gpfq::data::rng::Pcg;
use gpfq::theory::experiments::{measure_decay, measure_decay_subspace, measure_generalization};
use gpfq::util::bench::Table;
use gpfq::util::stats::ols_slope;

fn main() {
    let mut rng = Pcg::seed(2020);

    // ---- Theorem 2 decay in N0 --------------------------------------------
    let m = 32;
    let ns = [64usize, 128, 256, 512, 1024, 2048];
    let mut t = Table::new(
        &format!("Theorem 2 — relative error vs N0 (m={m}, Gaussian data, ternary)"),
        &["N0", "measured rel err", "theory shape log(N0)sqrt(m/N0)", "measured/theory"],
    );
    let mut logs_n = Vec::new();
    let mut logs_e = Vec::new();
    for &n in &ns {
        let p = measure_decay(&mut rng, m, n, 6);
        t.row(vec![
            n.to_string(),
            format!("{:.4}", p.rel_err),
            format!("{:.4}", p.predicted),
            format!("{:.3}", p.rel_err / p.predicted),
        ]);
        logs_n.push((n as f64).ln());
        logs_e.push(p.rel_err.ln());
    }
    t.emit("theory_thm2_decay");
    let slope = ols_slope(&logs_n, &logs_e);
    println!(
        "log-log slope of error vs N0: {slope:.3}  (theory: -0.5 up to the log factor)\n"
    );

    // ---- Theorem 2 growth in m ---------------------------------------------
    let mut t = Table::new(
        "Theorem 2 — relative error vs m (N0=1024)",
        &["m", "measured rel err", "theory shape"],
    );
    for &mm in &[8usize, 16, 32, 64, 128] {
        let p = measure_decay(&mut rng, mm, 1024, 6);
        t.row(vec![mm.to_string(), format!("{:.4}", p.rel_err), format!("{:.4}", p.predicted)]);
    }
    t.emit("theory_thm2_m");

    // ---- Lemma 16 subspace -------------------------------------------------
    let mut t = Table::new(
        "Lemma 16 — intrinsic dimension d governs the error (m=48, N0=512)",
        &["d", "measured rel err", "theory shape log(N0)sqrt(d/N0)"],
    );
    for &d in &[2usize, 4, 8, 16, 32, 48] {
        let p = measure_decay_subspace(&mut rng, 48, d, 512, 6);
        t.row(vec![d.to_string(), format!("{:.4}", p.rel_err), format!("{:.4}", p.predicted)]);
    }
    t.emit("theory_lemma16");

    // ---- Theorem 3 generalization -------------------------------------------
    let mut t = Table::new(
        "Theorem 3 — generalization in the data span (sigma normalized rows)",
        &["m", "N0", "median |z^T(w-q)|", "in-sample median", "theory shape"],
    );
    for &(mm, n) in &[(8usize, 256usize), (8, 1024), (16, 1024), (32, 2048)] {
        let p = measure_generalization(&mut rng, mm, n, 4, 16);
        t.row(vec![
            mm.to_string(),
            n.to_string(),
            format!("{:.5}", p.gen_err),
            format!("{:.5}", p.train_err),
            format!("{:.4}", p.predicted),
        ]);
    }
    t.emit("theory_thm3_generalization");
    println!("shapes should track the theory columns up to constants; see EXPERIMENTS.md E7-E9.");
}
