//! End-to-end driver over the FULL three-layer stack — every phase runs
//! through AOT artifacts on the PJRT runtime, proving the layers compose:
//!
//!  1. TRAIN a 784-128-64-10 MLP from Rust by looping the `train_step`
//!     HLO artifact (jax fwd/bwd lowered at build time) for several
//!     hundred SGD steps on the synthetic MNIST task, logging the loss.
//!  2. EVALUATE analog accuracy through the fused `mlp_fwd` artifact.
//!  3. QUANTIZE every layer with the GPFQ Pallas-kernel artifacts
//!     (`gpfq_m512_n{784,128,64}_b64_M3`) via the coordinator pipeline.
//!  4. EVALUATE the ternary network and report the accuracy drop,
//!     compression and per-phase throughput.
//!
//! Requires `make artifacts`.  Recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example e2e_pipeline [-- --steps N]

use std::sync::Arc;
use std::time::Instant;

use gpfq::coordinator::executor::Executor;
use gpfq::coordinator::pipeline::{quantize_network, PipelineConfig};
use gpfq::data::rng::Pcg;
use gpfq::data::synth::{generate, mnist_like_spec};
use gpfq::eval::metrics::{accuracy, accuracy_from_logits};
use gpfq::eval::report::acc;
use gpfq::nn::activations::Activation;
use gpfq::nn::matrix::Matrix;
use gpfq::nn::network::{NetworkBuilder, Shape};
use gpfq::quant::error::compression_ratio;
use gpfq::runtime::{Arg, Runtime};

const DIMS: [usize; 4] = [784, 128, 64, 10];
const BATCH: usize = 128;
const EVAL_BATCH: usize = 512;

fn he_init(rng: &mut Pcg, rows: usize, cols: usize) -> Matrix {
    let scale = (2.0 / rows as f64).sqrt();
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let Some(rt) = Runtime::try_default().map(Arc::new) else {
        eprintln!("e2e_pipeline needs AOT artifacts: run `make artifacts` first.");
        std::process::exit(1);
    };
    println!("PJRT platform: {}", rt.platform());
    let train_name = format!("train_step_b{BATCH}_{}", DIMS.map(|d| d.to_string()).join("x"));
    let fwd_name = format!("mlp_fwd_b{EVAL_BATCH}_{}", DIMS.map(|d| d.to_string()).join("x"));

    // ---- data --------------------------------------------------------------
    let sspec = mnist_like_spec(0);
    let train_set = generate(&sspec, 4096, 0, false);
    let test_set = generate(&sspec, 1024, 1, false);
    let y_onehot = train_set.one_hot();

    // ---- phase 1: training through the train_step artifact ------------------
    let mut rng = Pcg::seed(7);
    let mut params: Vec<Matrix> = Vec::new();
    for i in 0..DIMS.len() - 1 {
        params.push(he_init(&mut rng, DIMS[i], DIMS[i + 1]));
        params.push(Matrix::zeros(1, DIMS[i + 1])); // bias as 1-row matrix
    }
    let lr = 0.05f32;
    println!("training {steps} steps (batch {BATCH}) through `{train_name}` ...");
    let t0 = Instant::now();
    let mut losses: Vec<f64> = Vec::new();
    for step in 0..steps {
        let idx: Vec<usize> = (0..BATCH).map(|_| rng.below(train_set.len())).collect();
        let xb = train_set.x.gather_rows(&idx);
        let yb = y_onehot.gather_rows(&idx);
        let mut exec_args: Vec<Arg> = params.iter().map(Arg::Mat).collect();
        exec_args.push(Arg::Mat(&xb));
        exec_args.push(Arg::Mat(&yb));
        exec_args.push(Arg::Scalar(lr));
        let out = rt.execute(&train_name, &exec_args).expect("train_step failed");
        let loss = out.last().unwrap().at(0, 0) as f64;
        params = out[..out.len() - 1].to_vec();
        losses.push(loss);
        if step % 50 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    println!(
        "loss curve: {:.4} -> {:.4} ({:.1} steps/s, {:.2}s total)",
        losses[0],
        losses.last().unwrap(),
        steps as f64 / train_secs,
        train_secs
    );
    assert!(
        losses.last().unwrap() < &(0.5 * losses[0]),
        "training did not converge — loss {:.4} -> {:.4}",
        losses[0],
        losses.last().unwrap()
    );

    // ---- phase 2: analog evaluation through the mlp_fwd artifact -------------
    let eval_with_artifact = |params: &[Matrix]| -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut row = 0;
        while row < test_set.len() {
            let end = (row + EVAL_BATCH).min(test_set.len());
            let xb = test_set.x.rows_slice(row, end).pad_to(EVAL_BATCH, test_set.dim());
            let mut exec_args: Vec<Arg> = vec![Arg::Mat(&xb)];
            exec_args.extend(params.iter().map(Arg::Mat));
            let logits = &rt.execute(&fwd_name, &exec_args).expect("mlp_fwd failed")[0];
            let real = logits.rows_slice(0, end - row);
            correct +=
                (accuracy_from_logits(&real, &test_set.labels[row..end]) * (end - row) as f64) as usize;
            total += end - row;
            row = end;
        }
        correct as f64 / total as f64
    };
    let t1 = Instant::now();
    let analog_acc = eval_with_artifact(&params);
    println!(
        "analog test top-1 (via mlp_fwd artifact): {}  ({:.0} samples/s)",
        acc(analog_acc),
        test_set.len() as f64 / t1.elapsed().as_secs_f64()
    );

    // ---- phase 3: GPFQ quantization through the Pallas artifacts -------------
    // mirror the trained parameters into a native Network for the pipeline
    let mut b = NetworkBuilder::new(Shape::Flat(DIMS[0]), 0);
    b.dense(DIMS[1], Activation::Relu).dense(DIMS[2], Activation::Relu).dense(DIMS[3], Activation::None);
    let mut net = b.build();
    for (li, layer_idx) in net.quantizable_layers().into_iter().enumerate() {
        net.set_weights(layer_idx, params[2 * li].clone());
        if let gpfq::nn::Layer::Dense { b, .. } = &mut net.layers[layer_idx] {
            b.copy_from_slice(params[2 * li + 1].row(0));
        }
    }
    let native_acc = accuracy(&net, &test_set);
    println!("analog test top-1 (native forward):        {} (cross-check)", acc(native_acc));
    assert!((native_acc - analog_acc).abs() < 0.02, "artifact vs native eval diverged");

    let x_quant = train_set.x.rows_slice(0, 512);
    let cfg = PipelineConfig {
        c_alpha: 3.0,
        executor: Some(Executor::with_runtime(rt.clone(), 1)),
        ..Default::default()
    };
    let t2 = Instant::now();
    let out = quantize_network(&net, &x_quant, &cfg);
    let quant_secs = t2.elapsed().as_secs_f64();
    let total_blocks: usize = out.layer_reports.iter().map(|r| r.pjrt_blocks + r.native_blocks).sum();
    let pjrt_blocks: usize = out.layer_reports.iter().map(|r| r.pjrt_blocks).sum();
    println!(
        "quantized {} layers in {:.2}s — {pjrt_blocks}/{total_blocks} neuron blocks on the PJRT/Pallas path",
        out.layer_reports.len(),
        quant_secs
    );
    for r in &out.layer_reports {
        println!(
            "  {}: alpha {:.4}, fro_err {:.4}, median rel err {:.4} ({} pjrt / {} native blocks)",
            r.label, r.alpha, r.fro_err, r.median_rel_err, r.pjrt_blocks, r.native_blocks
        );
    }
    assert!(pjrt_blocks > 0, "expected the PJRT path to serve this shape");

    // ---- phase 4: quantized evaluation ---------------------------------------
    let q_acc = accuracy(&out.network, &test_set);
    println!(
        "\n=== E2E summary ===\nanalog {}  ->  ternary GPFQ {}  (drop {:+.4}, {:.1}x compression)",
        acc(analog_acc),
        acc(q_acc),
        q_acc - analog_acc,
        compression_ratio(3)
    );
    assert!(q_acc > analog_acc - 0.15, "quantization destroyed the network");
    println!("all phases ran through AOT artifacts; python was never invoked.");
}
