//! E3/E4/E5 — the paper's Section 6.2 CIFAR10 experiment (Table 1,
//! Figures 2a/2b), on the synthetic CIFAR stand-in, scaled to CPU.
//!
//!     cargo run --release --example cifar_cnn [-- --quick]
//!
//! Table 1: top-1 accuracy across bit budgets {log2(3), 2, 3, 4} ×
//! C_alpha ∈ {2..6} for Analog/GPFQ/MSQ.  Figure 2a: accuracy vs layers
//! quantized at each method's best config.  Figure 2b: histogram of the
//! quantized weights at the second conv layer.

use gpfq::config::preset_cifar;
use gpfq::coordinator::pipeline::{quantize_network, Method, PipelineConfig};
use gpfq::coordinator::sweep::{sweep, SweepConfig};
use gpfq::data::synth::{cifar_like_spec, generate};
use gpfq::eval::metrics::accuracy;
use gpfq::eval::report::{acc, dual_histogram_table, weight_histogram};
use gpfq::train::train;
use gpfq::util::bench::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut spec = preset_cifar(0);
    if quick {
        spec.quant.levels = vec![3, 16];
        spec.quant.c_alphas = vec![2.0, 4.0];
        spec.dataset.n_train = 1000;
        spec.train.epochs = 5;
    }
    let sspec = cifar_like_spec(spec.seed);
    let train_set = generate(&sspec, spec.dataset.n_train, 0, spec.dataset.augment);
    let test_set = generate(&sspec, spec.dataset.n_test, 1, false);
    let mut net = spec.build_network();
    println!("training {} on {} samples ...", net.summary(), train_set.len());
    train(&mut net, &train_set, &spec.train);
    let x_quant = train_set.x.rows_slice(0, spec.dataset.n_quant.min(train_set.len()));

    // ---- Table 1 ----------------------------------------------------------
    let cfg = SweepConfig {
        levels: spec.quant.levels.clone(),
        c_alphas: spec.quant.c_alphas.clone(),
        methods: vec![Method::Gpfq, Method::Msq],
        workers: spec.quant.workers,
        // the Table 1 grid is 40 cells — stream it through the engine in
        // bounded chunks so peak residency is O(chunk), not O(grid)
        chunk_cells: Some(8),
        ..Default::default()
    };
    println!("sweeping {}x{} grid x 2 methods ...", cfg.levels.len(), cfg.c_alphas.len());
    let res = sweep(&net, &x_quant, &test_set, &cfg);
    println!(
        "peak resident (engine-accounted): {:.1} KiB with {} of {} cells in flight",
        res.peak_resident_bytes as f64 / 1024.0,
        res.chunk_cells,
        res.points.len()
    );
    let mut table1 = Table::new(
        "Table 1 — CIFAR-like CNN top-1 test accuracy",
        &["bits", "C_alpha", "Analog", "GPFQ", "MSQ"],
    );
    for &m_levels in &spec.quant.levels {
        let bits = if m_levels == 3 { "log2(3)".to_string() } else { format!("{}", (m_levels as f64).log2()) };
        for &c in &spec.quant.c_alphas {
            let g = res.points.iter().find(|p| p.method == Method::Gpfq && p.levels == m_levels && p.c_alpha_requested == c).unwrap();
            let m = res.points.iter().find(|p| p.method == Method::Msq && p.levels == m_levels && p.c_alpha_requested == c).unwrap();
            table1.row(vec![bits.clone(), format!("{c}"), acc(res.analog_top1), acc(g.top1), acc(m.top1)]);
        }
    }
    table1.emit("table1_cifar");

    // paper's qualitative claims, checked programmatically:
    let best3_g = res.points.iter().filter(|p| p.method == Method::Gpfq && p.levels == 3).map(|p| p.top1).fold(f64::MIN, f64::max);
    let best3_m = res.points.iter().filter(|p| p.method == Method::Msq && p.levels == 3).map(|p| p.top1).fold(f64::MIN, f64::max);
    println!("ternary best: GPFQ {} vs MSQ {} (paper: GPFQ degrades gracefully, MSQ collapses)", acc(best3_g), acc(best3_m));

    // ---- Figure 2a: layer progression at best configs ---------------------
    let mut fig2a = Table::new(
        "Figure 2a — accuracy vs #layers quantized (best configs)",
        &["layers quantized", "GPFQ top-1", "MSQ top-1"],
    );
    let mut curves = Vec::new();
    let mut conv2_weights = Vec::new();
    for method in [Method::Gpfq, Method::Msq] {
        let best = res.best(method).unwrap();
        let cfg = PipelineConfig {
            method,
            levels: best.levels,
            c_alpha: best.c_alpha_f32(),
            capture_checkpoints: true,
            ..Default::default()
        };
        let out = quantize_network(&net, &x_quant, &cfg);
        curves.push(out.checkpoints.iter().map(|n| accuracy(n, &test_set)).collect::<Vec<_>>());
        // Figure 2b data: quantized weights of the 2nd quantizable layer
        let idx = out.layer_reports[1].layer_index;
        conv2_weights.push(out.network.layers[idx].weights().unwrap().data.clone());
    }
    for i in 0..curves[0].len() {
        fig2a.row(vec![(i + 1).to_string(), acc(curves[0][i]), acc(curves[1][i])]);
    }
    fig2a.emit("fig2a_cifar");

    // ---- Figure 2b: weight histograms at the 2nd conv layer ---------------
    println!("{}", weight_histogram("Figure 2b (GPFQ) — 2nd conv layer quantized weights", &conv2_weights[0], 17));
    println!("{}", weight_histogram("Figure 2b (MSQ) — 2nd conv layer quantized weights", &conv2_weights[1], 17));
    dual_histogram_table("Figure 2b — weight histogram", "gpfq", &conv2_weights[0], "msq", &conv2_weights[1], 17)
        .emit("fig2b_cifar");
}
