//! E6 — the paper's Section 6.3 VGG16/ImageNet experiment (Table 2), on
//! the ImageNet stand-in: a VGG-style network whose FC head holds ≥90% of
//! the weights (mirroring VGG16), quantized FC-only with the ternary
//! alphabet over C_alpha ∈ {2..5}, reporting top-1 and top-5.
//!
//!     cargo run --release --example imagenet_vgg

use gpfq::config::preset_imagenet;
use gpfq::coordinator::pipeline::Method;
use gpfq::coordinator::sweep::{sweep, SweepConfig};
use gpfq::data::synth::{generate, imagenet_like_spec};
use gpfq::eval::report::acc;
use gpfq::nn::Layer;
use gpfq::train::train;
use gpfq::util::bench::Table;

fn main() {
    let spec = preset_imagenet(0);
    let sspec = imagenet_like_spec(spec.seed, spec.dataset.classes);
    let train_set = generate(&sspec, spec.dataset.n_train, 0, false);
    let test_set = generate(&sspec, spec.dataset.n_test, 1, false);
    let mut net = spec.build_network();

    // check the VGG16 weight-distribution property we rely on
    let fc: usize = net
        .layers
        .iter()
        .filter_map(|l| match l {
            Layer::Dense { w, .. } => Some(w.data.len()),
            _ => None,
        })
        .sum();
    println!(
        "{}  ({:.1}% of {} weights in FC layers; paper: ~90% for VGG16)",
        net.summary(),
        100.0 * fc as f64 / net.weight_count() as f64,
        net.weight_count()
    );

    println!("training on {} samples ...", train_set.len());
    train(&mut net, &train_set, &spec.train);
    let x_quant = train_set.x.rows_slice(0, spec.dataset.n_quant.min(train_set.len()));

    let cfg = SweepConfig {
        levels: vec![3],
        c_alphas: spec.quant.c_alphas.clone(),
        methods: vec![Method::Gpfq, Method::Msq],
        fc_only: true,
        workers: spec.quant.workers,
        topk: true,
        // the FC-dominated VGG makes resident cell networks the memory
        // term: keep only half the grid in flight
        chunk_cells: Some(4),
    };
    println!("sweeping C_alpha in {:?}, ternary, FC-only ...", cfg.c_alphas);
    let res = sweep(&net, &x_quant, &test_set, &cfg);
    println!(
        "peak resident (engine-accounted): {:.1} KiB with {} of {} cells in flight",
        res.peak_resident_bytes as f64 / 1024.0,
        res.chunk_cells,
        res.points.len()
    );

    let mut t = Table::new(
        "Table 2 — ImageNet-like VGG test accuracy (ternary, FC layers only)",
        &["C_alpha", "Analog top-1", "Analog top-5", "GPFQ top-1", "GPFQ top-5", "MSQ top-1", "MSQ top-5"],
    );
    for &c in &spec.quant.c_alphas {
        let g = res.points.iter().find(|p| p.method == Method::Gpfq && p.c_alpha_requested == c).unwrap();
        let m = res.points.iter().find(|p| p.method == Method::Msq && p.c_alpha_requested == c).unwrap();
        t.row(vec![
            format!("{c}"),
            acc(res.analog_top1),
            acc(res.analog_top5),
            acc(g.top1),
            acc(g.top5),
            acc(m.top1),
            acc(m.top5),
        ]);
    }
    t.emit("table2_imagenet");

    let bg = res.best(Method::Gpfq).unwrap();
    let bm = res.best(Method::Msq).unwrap();
    println!(
        "best GPFQ within {:.2}% (top-1) / {:.2}% (top-5) of analog; best MSQ within {:.2}% / {:.2}%",
        100.0 * (res.analog_top1 - bg.top1),
        100.0 * (res.analog_top5 - bg.top5),
        100.0 * (res.analog_top1 - bm.top1),
        100.0 * (res.analog_top5 - bm.top5),
    );
    println!(
        "C_alpha spread: GPFQ {:.4} vs MSQ {:.4} (paper: MSQ notably unstable in C_alpha)",
        res.spread(Method::Gpfq, 3),
        res.spread(Method::Msq, 3)
    );
}
