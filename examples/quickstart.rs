//! Quickstart: train a small float MLP on a synthetic task, quantize it
//! with GPFQ and with the MSQ baseline, and compare.
//!
//!     cargo run --release --example quickstart
//!
//! Runs in seconds on the native path (no artifacts needed); if
//! `make artifacts` has been run, layers whose shapes match an AOT module
//! are executed through PJRT instead and the report says so.

use gpfq::config::preset_mnist;
use gpfq::coordinator::pipeline::{quantize_network, Method, PipelineConfig};
use gpfq::data::synth::{generate, mnist_like_spec};
use gpfq::eval::metrics::accuracy;
use gpfq::eval::report::acc;
use gpfq::quant::error::compression_ratio;
use gpfq::train::train;
use gpfq::util::bench::Table;

fn main() {
    let mut spec = preset_mnist(0);
    spec.dataset.n_train = 1200;
    spec.dataset.n_test = 400;
    spec.train.epochs = 5;
    spec.model = gpfq::config::ModelSpec::Mlp { hidden: vec![64, 32] };

    // 1. data + float training (the paper assumes this part as given)
    let sspec = mnist_like_spec(spec.seed);
    let train_set = generate(&sspec, spec.dataset.n_train, 0, false);
    let test_set = generate(&sspec, spec.dataset.n_test, 1, false);
    let mut net = spec.build_network();
    println!("training {} ...", net.summary());
    train(&mut net, &train_set, &spec.train);
    let analog = accuracy(&net, &test_set);

    // 2. quantize: GPFQ (paper eq. (2)/(3)) vs MSQ baseline, ternary
    let x_quant = train_set.x.rows_slice(0, 512.min(train_set.len()));
    let mut table = Table::new(
        "Quickstart: ternary quantization (M=3)",
        &["method", "C_alpha", "test top-1", "drop vs analog", "compression"],
    );
    for method in [Method::Gpfq, Method::Msq] {
        for c_alpha in [2.0f32, 4.0] {
            let cfg = PipelineConfig { method, c_alpha, ..Default::default() };
            let out = quantize_network(&net, &x_quant, &cfg);
            let a = accuracy(&out.network, &test_set);
            table.row(vec![
                format!("{method:?}"),
                format!("{c_alpha}"),
                acc(a),
                format!("{:+.4}", a - analog),
                format!("{:.1}x", compression_ratio(3)),
            ]);
            let pjrt_blocks: usize = out.layer_reports.iter().map(|r| r.pjrt_blocks).sum();
            if pjrt_blocks > 0 {
                println!("  ({method:?} C_alpha={c_alpha}: {pjrt_blocks} neuron blocks ran via PJRT artifacts)");
            }
        }
    }
    println!("\nanalog test top-1: {}\n", acc(analog));
    println!("{}", table.render());
    println!("GPFQ tracks the analog network; MSQ collapses at small alphabets —");
    println!("the paper's Figure 1a in miniature. Try `gpfq sweep --preset mnist`.");
}
